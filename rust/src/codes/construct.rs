//! Constructors for every scheme. All global parities are Cauchy rows
//! (`α_{i,j} = (a_i + b_j)^{-1}`, `a_i = i`, `b_j = k + j`), which makes
//! the base stripe MDS for every (k, r) we use (k + r ≤ 256). See
//! DESIGN.md for the Vandermonde→Cauchy substitution note.

use super::{Equation, Scheme, SchemeKind};
use crate::gf::{self, GfMatrix};

/// Cauchy evaluation points for a (k, r) base stripe.
fn cauchy_points(k: usize, r: usize) -> (Vec<u8>, Vec<u8>) {
    assert!(k + r <= 256, "k + r must fit in GF(2^8)");
    let xs: Vec<u8> = (0..k as u16).map(|i| i as u8).collect();
    let ys: Vec<u8> = (k as u16..(k + r) as u16).map(|i| i as u8).collect();
    (xs, ys)
}

/// α_{i,j} coefficient of data block i in global parity j.
fn alpha(k: usize, i: usize, j: usize) -> u8 {
    gf::inv((i as u8) ^ ((k + j) as u8))
}

/// Base generator: identity over data rows + Cauchy global-parity rows.
/// Returns an (k + r) × k matrix; callers append local-parity rows.
fn base_generator(k: usize, r: usize) -> GfMatrix {
    let (xs, ys) = cauchy_points(k, r);
    let cauchy = GfMatrix::cauchy(&ys, &xs); // r x k: row j = α_{·,j}
    let mut g = GfMatrix::zeros(k + r, k);
    for i in 0..k {
        g.set(i, i, 1);
    }
    for j in 0..r {
        for i in 0..k {
            g.set(k + j, i, cauchy.get(j, i));
        }
    }
    g
}

/// The r global-parity definition equations.
fn global_equations(k: usize, r: usize) -> Vec<Equation> {
    (0..r)
        .map(|j| {
            let mut terms: Vec<(usize, u8)> = vec![(k + j, 1)];
            terms.extend((0..k).map(|i| (i, alpha(k, i, j))));
            Equation { terms, local: false }
        })
        .collect()
}

/// Split `items` into `p` contiguous runs whose sizes differ by at most
/// one; the *later* groups receive the larger sizes (matches the paper's
/// (6,2,2) CP-Uniform example where the second group has 4 items).
fn even_contiguous(items: &[usize], p: usize) -> Vec<Vec<usize>> {
    assert!(p >= 1 && p <= items.len());
    let total = items.len();
    let small = total / p;
    let n_large = total % p;
    let mut groups = Vec::with_capacity(p);
    let mut at = 0;
    for j in 0..p {
        let sz = if j < p - n_large { small } else { small + 1 };
        groups.push(items[at..at + sz].to_vec());
        at += sz;
    }
    groups
}

/// Append a local-parity row computed as `Σ coeff · row(member)` and the
/// matching group equation.
fn push_local_parity(
    gen: &mut Vec<Vec<u8>>,
    eqs: &mut Vec<Equation>,
    members: &[(usize, u8)],
    k: usize,
    lp_block: usize,
) {
    let mut row = vec![0u8; k];
    for &(b, c) in members {
        for (col, v) in row.iter_mut().enumerate() {
            *v ^= gf::mul(c, gen[b][col]);
        }
    }
    gen.push(row);
    let mut terms = vec![(lp_block, 1u8)];
    terms.extend_from_slice(members);
    eqs.push(Equation { terms, local: true });
}

/// Assemble a [`Scheme`] from the base generator plus per-group member
/// lists with coefficients.
fn assemble(
    kind: SchemeKind,
    k: usize,
    r: usize,
    member_groups: Vec<Vec<(usize, u8)>>,
    cascade: bool,
    guaranteed_tolerance: usize,
) -> Scheme {
    let p = member_groups.len();
    let base = base_generator(k, r);
    let mut gen: Vec<Vec<u8>> = (0..k + r).map(|b| base.row(b).to_vec()).collect();
    let mut local_eqs = Vec::new();
    for (j, members) in member_groups.iter().enumerate() {
        push_local_parity(&mut gen, &mut local_eqs, members, k, k + r + j);
    }
    if cascade {
        // L1 + ... + Lp + Gr = 0 (eq. (4)/(9)).
        let mut terms: Vec<(usize, u8)> = (0..p).map(|j| (k + r + j, 1u8)).collect();
        terms.push((k + r - 1, 1));
        local_eqs.push(Equation { terms, local: true });
    }
    let scheme = Scheme {
        kind,
        k,
        r,
        p,
        generator: GfMatrix::from_rows(&gen),
        local_eqs,
        global_eqs: global_equations(k, r),
        groups: member_groups
            .iter()
            .map(|g| g.iter().map(|&(b, _)| b).collect())
            .collect(),
        guaranteed_tolerance,
    };
    debug_assert!(scheme.equations_hold(), "{kind:?} ({k},{r}) equations broken");
    scheme
}

/// Plain (k, r) Cauchy-RS MDS stripe — the §IV-B base. No locality.
pub fn rs(k: usize, r: usize) -> Scheme {
    assemble(SchemeKind::Rs, k, r, Vec::new(), false, r)
}

/// Azure LRC (§II-B): p even *data* groups, XOR local parities.
pub fn azure(k: usize, r: usize, p: usize) -> Scheme {
    let data: Vec<usize> = (0..k).collect();
    let groups = even_contiguous(&data, p)
        .into_iter()
        .map(|g| g.into_iter().map(|b| (b, 1u8)).collect())
        .collect();
    assemble(SchemeKind::AzureLrc, k, r, groups, false, r + 1)
}

/// Azure LRC+1 (§II-B): a (k, r, p−1) Azure LRC plus one XOR local parity
/// covering the r global parities.
pub fn azure_plus1(k: usize, r: usize, p: usize) -> Scheme {
    assert!(p >= 2, "Azure LRC+1 needs at least one data group plus the parity group");
    let data: Vec<usize> = (0..k).collect();
    let mut groups: Vec<Vec<(usize, u8)>> = even_contiguous(&data, p - 1)
        .into_iter()
        .map(|g| g.into_iter().map(|b| (b, 1u8)).collect())
        .collect();
    groups.push((k..k + r).map(|b| (b, 1u8)).collect());
    assemble(SchemeKind::AzureLrcPlus1, k, r, groups, false, r + 1)
}

/// Optimal Cauchy LRC (§II-B): p even data groups; each local parity is
/// the XOR of its group's data blocks plus the XOR of *all* global
/// parities, which buys optimal minimum distance r+2 (tolerates r+1).
pub fn optimal_cauchy(k: usize, r: usize, p: usize) -> Scheme {
    let data: Vec<usize> = (0..k).collect();
    let groups = even_contiguous(&data, p)
        .into_iter()
        .map(|g| {
            let mut m: Vec<(usize, u8)> = g.into_iter().map(|b| (b, 1u8)).collect();
            m.extend((k..k + r).map(|b| (b, 1u8)));
            m
        })
        .collect();
    assemble(SchemeKind::OptimalCauchy, k, r, groups, false, r + 1)
}

/// Distribute data contiguously/evenly into p groups, then deal the given
/// parity blocks round-robin onto the currently-smallest groups. This is
/// the "uniform" grouping that reproduces Google's balanced localities
/// (and the paper's Table III Uniform rows — see codes::tests).
fn uniform_groups(k: usize, p: usize, parities: &[usize]) -> Vec<Vec<usize>> {
    let data: Vec<usize> = (0..k).collect();
    let mut groups = even_contiguous(&data, p);
    for &g in parities {
        // smallest group; ties broken toward the LAST group, matching the
        // paper's (6,2,2) CP-Uniform example (G1 lands in group 2).
        let (j, _) = groups
            .iter()
            .enumerate()
            .min_by_key(|(j, grp)| (grp.len(), p - *j))
            .unwrap();
        groups[j].push(g);
    }
    groups
}

/// Uniform Cauchy LRC (§II-B): data and all r globals grouped uniformly,
/// XOR local parities. Tolerates any r failures (distance r+1).
pub fn uniform_cauchy(k: usize, r: usize, p: usize) -> Scheme {
    let parities: Vec<usize> = (k..k + r).collect();
    let groups = uniform_groups(k, p, &parities)
        .into_iter()
        .map(|g| g.into_iter().map(|b| (b, 1u8)).collect())
        .collect();
    assemble(SchemeKind::UniformCauchy, k, r, groups, false, r)
}

/// CP-Azure (§IV-C): even data groups; local parity `Lj` uses the *last
/// global parity's* coefficients restricted to its group (eq. (6)), so
/// `L1 + … + Lp = Gr` by construction.
pub fn cp_azure(k: usize, r: usize, p: usize) -> Scheme {
    let data: Vec<usize> = (0..k).collect();
    let groups = even_contiguous(&data, p)
        .into_iter()
        .map(|g| g.into_iter().map(|i| (i, alpha(k, i, r - 1))).collect())
        .collect();
    assemble(SchemeKind::CpAzure, k, r, groups, true, r)
}

/// The appendix coefficients for CP-Uniform: nonzero γ̄_i, η̄_j with
/// `γ̄_i + Σ_j η̄_j α_{i,j} = 0` (Theorem 1), normalized by η̄_r so that
/// `Gr = Σ γ_i D_i + Σ_{j<r} η_j G_j` (eq. (10)).
pub fn cp_uniform_coefficients(k: usize, r: usize) -> (Vec<u8>, Vec<u8>) {
    let (xs, ys) = cauchy_points(k, r);
    // γ̄_i = Π_z (a_i + b_z)^{-1}
    let gamma_bar: Vec<u8> = xs
        .iter()
        .map(|&a| ys.iter().fold(1u8, |acc, &b| gf::mul(acc, gf::inv(a ^ b))))
        .collect();
    // η̄_j = Π_{z≠j} (b_j + b_z)^{-1}
    let eta_bar: Vec<u8> = (0..r)
        .map(|j| {
            (0..r)
                .filter(|&z| z != j)
                .fold(1u8, |acc, z| gf::mul(acc, gf::inv(ys[j] ^ ys[z])))
        })
        .collect();
    let last = eta_bar[r - 1];
    let gamma: Vec<u8> = gamma_bar.iter().map(|&g| gf::div(g, last)).collect();
    let eta: Vec<u8> = eta_bar[..r - 1].iter().map(|&e| gf::div(e, last)).collect();
    (gamma, eta)
}

/// CP-Uniform (§IV-D): the k data blocks and the first r−1 globals are
/// grouped uniformly; member coefficients come from
/// [`cp_uniform_coefficients`], so `L1 + … + Lp = Gr` (eq. (9)).
pub fn cp_uniform(k: usize, r: usize, p: usize) -> Scheme {
    let (gamma, eta) = cp_uniform_coefficients(k, r);
    let parities: Vec<usize> = (k..k + r - 1).collect();
    let groups = uniform_groups(k, p, &parities)
        .into_iter()
        .map(|g| {
            g.into_iter()
                .map(|b| {
                    let c = if b < k { gamma[b] } else { eta[b - k] };
                    (b, c)
                })
                .collect()
        })
        .collect();
    assemble(SchemeKind::CpUniform, k, r, groups, true, r)
}

/// EXTENSION — CP applied atop Azure LRC+1 (§IV-E): p−1 CP-Azure data
/// groups whose local parities decompose `Gr` (so `L1+…+L(p−1) = Gr`,
/// cascading), plus one XOR local parity over the r global parities
/// (Azure LRC+1's parity-group protection).
pub fn cp_plus1(k: usize, r: usize, p: usize) -> Scheme {
    assert!(p >= 3, "CP-LRC+1 needs ≥2 data groups plus the parity group");
    let data: Vec<usize> = (0..k).collect();
    let mut groups: Vec<Vec<(usize, u8)>> = even_contiguous(&data, p - 1)
        .into_iter()
        .map(|g| g.into_iter().map(|i| (i, alpha(k, i, r - 1))).collect())
        .collect();
    groups.push((k..k + r).map(|b| (b, 1u8)).collect());
    let base = base_generator(k, r);
    let mut gen: Vec<Vec<u8>> = (0..k + r).map(|b| base.row(b).to_vec()).collect();
    let mut local_eqs = Vec::new();
    for (j, members) in groups.iter().enumerate() {
        push_local_parity(&mut gen, &mut local_eqs, members, k, k + r + j);
    }
    // cascade over the p−1 DATA-group parities only: Σ L_j = Gr
    let mut terms: Vec<(usize, u8)> = (0..p - 1).map(|j| (k + r + j, 1u8)).collect();
    terms.push((k + r - 1, 1));
    local_eqs.push(Equation { terms, local: true });
    let scheme = Scheme {
        kind: SchemeKind::CpPlus1,
        k,
        r,
        p,
        generator: GfMatrix::from_rows(&gen),
        local_eqs,
        global_eqs: global_equations(k, r),
        groups: groups.iter().map(|g| g.iter().map(|&(b, _)| b).collect()).collect(),
        guaranteed_tolerance: r,
    };
    debug_assert!(scheme.equations_hold());
    scheme
}

/// EXTENSION — CP applied atop Optimal Cauchy LRC (§IV-E): local parity
/// `Lj` carries the `Gr` decomposition over its data group *plus* all
/// first r−1 global parities with per-group coefficients `c_{j,m}` chosen
/// nonzero and XOR-cancelling (`Σ_j c_{j,m} = 0`), so the cascade
/// `ΣLj = Gr` is preserved while every group can repair any `G_m`
/// locally — the Optimal-style "globals in every group" property.
pub fn cp_optimal(k: usize, r: usize, p: usize) -> Scheme {
    assert!(p >= 2 && r >= 2);
    let data: Vec<usize> = (0..k).collect();
    let data_groups = even_contiguous(&data, p);
    // cancelling coefficients: c_{j,m} = x_j for j < p−1 and
    // c_{p−1,m} = XOR of the others, with x_j distinct nonzero; retry the
    // base point if the tail coefficient collapses to zero.
    let mut coeffs = vec![vec![0u8; r - 1]; p];
    for m in 0..r - 1 {
        let mut basep = 1u8 + m as u8;
        loop {
            let mut tail = 0u8;
            for (j, row) in coeffs.iter_mut().enumerate().take(p - 1) {
                let c = gf::pow(basep, j as u32 + 1);
                row[m] = c;
                tail ^= c;
            }
            if tail != 0 {
                coeffs[p - 1][m] = tail;
                break;
            }
            basep = basep.wrapping_add(1).max(1);
        }
    }
    let groups: Vec<Vec<(usize, u8)>> = data_groups
        .iter()
        .enumerate()
        .map(|(j, g)| {
            let mut m: Vec<(usize, u8)> =
                g.iter().map(|&i| (i, alpha(k, i, r - 1))).collect();
            m.extend((0..r - 1).map(|gm| (k + gm, coeffs[j][gm])));
            m
        })
        .collect();
    let mut scheme = assemble(SchemeKind::CpOptimal, k, r, groups, true, r);
    scheme.guaranteed_tolerance = r;
    scheme
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_contiguous_sizes() {
        let items: Vec<usize> = (0..7).collect();
        let g = even_contiguous(&items, 2);
        assert_eq!(g[0], vec![0, 1, 2]);
        assert_eq!(g[1], vec![3, 4, 5, 6]);
        let g = even_contiguous(&(0..20).collect::<Vec<_>>(), 5);
        assert!(g.iter().all(|x| x.len() == 4));
    }

    #[test]
    fn theorem1_appendix_identity() {
        // γ̄_i + Σ_j η̄_j α_{i,j} = 0, verified numerically for several (k, r).
        for (k, r) in [(6, 2), (16, 3), (48, 4), (96, 5)] {
            let (xs, ys) = cauchy_points(k, r);
            let gamma_bar: Vec<u8> = xs
                .iter()
                .map(|&a| ys.iter().fold(1u8, |acc, &b| gf::mul(acc, gf::inv(a ^ b))))
                .collect();
            let eta_bar: Vec<u8> = (0..r)
                .map(|j| {
                    (0..r)
                        .filter(|&z| z != j)
                        .fold(1u8, |acc, z| gf::mul(acc, gf::inv(ys[j] ^ ys[z])))
                })
                .collect();
            for i in 0..k {
                let mut acc = gamma_bar[i];
                for j in 0..r {
                    acc ^= gf::mul(eta_bar[j], alpha(k, i, j));
                }
                assert_eq!(acc, 0, "k={k} r={r} i={i}");
            }
        }
    }

    #[test]
    fn cp_uniform_eq10_identity() {
        // Gr = Σ γ_i D_i + Σ_{j<r} η_j G_j as generator rows.
        for (k, r) in [(6, 2), (24, 2), (48, 4), (96, 5)] {
            let (gamma, eta) = cp_uniform_coefficients(k, r);
            assert!(gamma.iter().all(|&c| c != 0));
            assert!(eta.iter().all(|&c| c != 0));
            let base = base_generator(k, r);
            for col in 0..k {
                let mut acc = 0u8;
                for i in 0..k {
                    acc ^= gf::mul(gamma[i], base.get(i, col));
                }
                for j in 0..r - 1 {
                    acc ^= gf::mul(eta[j], base.get(k + j, col));
                }
                assert_eq!(acc, base.get(k + r - 1, col), "k={k} r={r} col={col}");
            }
        }
    }

    #[test]
    fn base_stripe_is_mds() {
        // any k rows of the (k + r) base generator have rank k
        for (k, r) in [(6, 2), (10, 3), (12, 4)] {
            let g = base_generator(k, r);
            // sample a handful of k-subsets deterministically: drop each
            // possible set of r rows (choose(k+r, r) is small here).
            let n = k + r;
            let mut drop = vec![0usize; r];
            fn rec(
                g: &GfMatrix,
                n: usize,
                k: usize,
                drop: &mut Vec<usize>,
                depth: usize,
                start: usize,
            ) {
                if depth == drop.len() {
                    let keep: Vec<usize> =
                        (0..n).filter(|b| !drop.contains(b)).collect();
                    assert_eq!(g.select_rows(&keep).rank(), k, "drop={drop:?}");
                    return;
                }
                for b in start..n {
                    drop[depth] = b;
                    rec(g, n, k, drop, depth + 1, b + 1);
                }
            }
            rec(&g, n, k, &mut drop, 0, 0);
        }
    }

    #[test]
    fn azure_group_sizes_match_paper_examples() {
        let s = azure(24, 2, 2);
        assert_eq!(s.groups[0].len(), 12);
        assert_eq!(s.groups[1].len(), 12);
        let s = azure_plus1(6, 2, 2);
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].len(), 6); // all data in one group
        assert_eq!(s.groups[1], vec![6, 7]); // G1, G2
    }

    #[test]
    fn uniform_grouping_balances_data_and_parity() {
        // (16,3,2): data split 8/8, globals dealt to smallest → sizes 10/9.
        let s = uniform_cauchy(16, 3, 2);
        let mut sizes: Vec<usize> = s.groups.iter().map(|g| g.len()).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![9, 10]);
        // every global parity is in exactly one group
        let all: Vec<usize> = s.groups.concat();
        for g in 16..19 {
            assert_eq!(all.iter().filter(|&&b| b == g).count(), 1);
        }
    }

    #[test]
    fn cp_uniform_groups_match_paper_6_2_2() {
        let s = cp_uniform(6, 2, 2);
        // paper Fig 3(c): groups (D1,D2,D3) and (D4,D5,D6,G1)
        assert_eq!(s.groups[0], vec![0, 1, 2]);
        assert_eq!(s.groups[1], vec![3, 4, 5, 6]);
    }

    #[test]
    fn cp_extensions_cascade_and_tolerance() {
        // CP-LRC+1 (needs p ≥ 3) and CP-Optimal: equations hold, the
        // cascade identity holds, and the guaranteed tolerance r is
        // verified by exhaustive census at small parameters.
        let plus1 = cp_plus1(8, 3, 3);
        assert!(plus1.equations_hold());
        // cascade spans the data-group parities only
        for c in 0..plus1.k {
            let mut sum = 0u8;
            for j in 0..2 {
                sum ^= plus1.generator.get(plus1.local_parity(j), c);
            }
            assert_eq!(sum, plus1.generator.get(plus1.k + plus1.r - 1, c));
        }
        let opt = cp_optimal(6, 3, 2);
        assert!(opt.equations_hold());
        for c in 0..opt.k {
            let mut sum = 0u8;
            for j in 0..opt.p {
                sum ^= opt.generator.get(opt.local_parity(j), c);
            }
            assert_eq!(sum, opt.generator.get(opt.k + opt.r - 1, c));
        }
        // exhaustive tolerance census
        for s in [&plus1, &opt] {
            let n = s.n();
            let t = s.guaranteed_tolerance;
            let mut pat = vec![0usize; t];
            fn rec(s: &Scheme, n: usize, pat: &mut Vec<usize>, d: usize, start: usize) {
                if d == pat.len() {
                    assert!(s.recoverable(pat), "{:?} pattern {:?}", s.kind, pat);
                    return;
                }
                for b in start..n {
                    pat[d] = b;
                    rec(s, n, pat, d + 1, b + 1);
                }
            }
            rec(s, n, &mut pat, 0, 0);
        }
    }

    #[test]
    fn cp_optimal_globals_repair_locally() {
        // the Optimal-style benefit: any first global repairs from one group
        let s = cp_optimal(6, 3, 2);
        for m in 0..s.r - 1 {
            let plan = crate::repair::plan_single(&s, s.k + m);
            assert!(plan.fully_local(), "G{} should repair locally", m + 1);
            assert!(plan.cost(s.k) < s.k);
        }
        // and all local-parity coefficients for globals are nonzero
        for j in 0..s.p {
            for m in 0..s.r - 1 {
                let eq = &s.local_eqs[j];
                assert!(eq.coeff(s.k + m).is_some_and(|c| c != 0));
            }
        }
    }

    #[test]
    fn cp_azure_local_coeffs_are_gr_coeffs() {
        let s = cp_azure(6, 2, 2);
        // L1 row must equal α_{1..3, r} on its group, zero elsewhere.
        for i in 0..3 {
            assert_eq!(s.generator.get(8, i), alpha(6, i, 1));
            assert_eq!(s.generator.get(9, i), 0);
        }
        for i in 3..6 {
            assert_eq!(s.generator.get(8, i), 0);
            assert_eq!(s.generator.get(9, i), alpha(6, i, 1));
        }
    }
}
