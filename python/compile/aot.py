"""AOT pipeline: lower the L2 graph to HLO *text* artifacts for the Rust
PJRT runtime.

HLO text -- NOT ``lowered.compile()`` / serialized HloModuleProto -- is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifact naming: ``gf_matmul_r{R}_k{K}_b{B}.hlo.txt`` -- the Rust runtime
parses the envelope from the file name (rust/src/runtime/mod.rs). Two
envelopes cover the paper's P1-P8 (max k = 96, max r+p = 9); blocks wider
than B are sharded by the runtime, smaller shapes are zero-padded (a zero
GF coefficient contributes nothing).
"""

import argparse
import hashlib
import os
import sys

from jax._src.lib import xla_client as xc

from .model import encode_lowered

#: (R, K, B) envelopes to ship. B is the byte-axis shard width.
ENVELOPES = [
    (4, 32, 65536),   # narrow stripes (P1, P2, P5): r+p <= 4, k <= 32
    (12, 128, 65536), # wide stripes (P3..P8): r+p <= 9, k <= 96
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # ``as_hlo_text()`` ELIDES large constants ("constant({...})"), which
    # silently zeroes the embedded GF log/exp tables after the text
    # round-trip -- print with print_large_constants instead.
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.get_hlo_module().to_string(opts)


def build(outdir: str, envelopes=None, verbose=True) -> list:
    os.makedirs(outdir, exist_ok=True)
    written = []
    for (r, k, b) in envelopes or ENVELOPES:
        text = to_hlo_text(encode_lowered(r, k, b))
        name = f"gf_matmul_r{r}_k{k}_b{b}.hlo.txt"
        path = os.path.join(outdir, name)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:12]
        written.append((path, len(text), digest))
        if verbose:
            print(f"wrote {path}: {len(text)} chars, sha256 {digest}")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
