"""L2 -- the stripe-codec compute graph in JAX, calling the L1 kernel.

The paper's compute hot-spot is the stripe codec: parity generation on
the write path (SS V-B encoding) and erasure-decoding combine on the repair
path. Both are one GF(2^8) matrix multiplication:

* encode:  ``parities[R,B] = P[R,K] (x) data[K,B]``  (P = parity rows of
  the scheme's generator matrix, shipped from Rust at call time);
* decode:  ``lost[R,B]   = W[R,K] (x) survivors[K,B]`` (W = the inverted
  surviving-generator weights the Rust coordinator computes per plan).

Because the coefficient matrix is a *runtime input*, one AOT artifact per
shape envelope serves every scheme, every parameter set, and both paths --
that is what keeps Python entirely off the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import gf_matmul


def encode_fn(coeff, data):
    """The jitted graph the AOT pipeline lowers (tuple output -- the Rust
    loader unwraps a 1-tuple; see /opt/xla-example/load_hlo)."""
    return (gf_matmul(coeff, data),)


def encode_lowered(r_dim, k, b):
    """Lower ``encode_fn`` for a concrete (R, K, B) envelope."""
    coeff = jax.ShapeDtypeStruct((r_dim, k), jnp.uint8)
    data = jax.ShapeDtypeStruct((k, b), jnp.uint8)
    return jax.jit(encode_fn).lower(coeff, data)


def stripe_roundtrip(gen_rows, data, erase, keep):
    """Test-path helper (never AOT'd): encode a stripe with generator rows
    ``gen_rows`` (n x k), erase ``erase`` blocks, decode them back from the
    ``keep`` survivors via matrix inversion over GF(2^8) -- all in terms of
    the same kernel, proving encode/decode compose.

    Returns:
      (stripe, reconstructed) -- (n, B) and (len(erase), B) uint8 arrays.
    """
    import numpy as np

    from .kernels import gf_matmul_np
    from .kernels.ref import gf_mul_np

    gen = np.asarray(gen_rows, np.uint8)
    stripe = gf_matmul_np(gen, np.asarray(data, np.uint8))  # (n, B)

    sub = gen[keep, :]  # (k, k)
    inv = gf_inv_np(sub)
    # weights for each erased block: row_e . inv
    w = gf_matmul_np(gen[erase, :], inv)  # (len(erase), k)
    rec = gf_matmul(jnp.asarray(w), jnp.asarray(stripe[keep, :]))
    return stripe, np.asarray(rec)


def gf_inv_np(m):
    """Gauss-Jordan inversion over GF(2^8) in numpy (test-path only)."""
    import numpy as np

    from .kernels.gf_matmul import gf_tables
    from .kernels.ref import gf_mul_np

    log, exp = gf_tables()

    def inv_scalar(x):
        assert x != 0
        return exp[(255 - log[x]) % 255]

    n = m.shape[0]
    a = m.astype(np.uint8).copy()
    b = np.eye(n, dtype=np.uint8)
    for col in range(n):
        piv = next(r for r in range(col, n) if a[r, col] != 0)
        if piv != col:
            a[[col, piv]] = a[[piv, col]]
            b[[col, piv]] = b[[piv, col]]
        d = inv_scalar(a[col, col])
        a[col] = gf_mul_np(a[col], d)
        b[col] = gf_mul_np(b[col], d)
        for r in range(n):
            if r != col and a[r, col] != 0:
                f = a[r, col]
                a[r] = a[r] ^ gf_mul_np(a[col], f)
                b[r] = b[r] ^ gf_mul_np(b[col], f)
    return b
