"""L1 — the GF(2^8) matrix-multiply hot-spot as a Pallas kernel.

The stripe codec's encode (parity generation) and decode (inverted-matrix
combine) are both ``out[R,B] = sum_k coeff[R,k] * data[k,B]`` over
GF(2^8): multiplication via log/antilog tables, accumulation via XOR.

Hardware adaptation (DESIGN.md §3): the paper's prototype leans on
Jerasure's SIMD table lookups on x86. On a TPU-shaped memory hierarchy we
instead tile the byte axis with ``BlockSpec`` so each grid step streams a
``(K, TB)`` data tile HBM→VMEM while the (tiny) coefficient matrix and the
log/exp tables stay VMEM-resident, and the inner ``fori_loop`` performs
the K-step gather+XOR reduction per tile. GF(2^8) multiplication is not
an MXU primitive, so the roofline here is the gather/VPU path, not the
systolic array — see EXPERIMENTS.md §Perf for the footprint analysis.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which is
exactly what the Rust runtime loads (see the repo-root README).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

# ---------------------------------------------------------------- tables

#: Primitive polynomial x^8 + x^4 + x^3 + x^2 + 1 — same field as the
#: Rust substrate (rust/src/gf/tables.rs) and Jerasure w=8.
POLY = 0x11D


@functools.lru_cache(maxsize=None)
def _tables():
    exp = np.zeros(510, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    exp[255:510] = exp[0:255]
    return log, exp


def gf_tables():
    """(log[256] int32, exp[510] uint8) numpy tables for GF(2^8)."""
    return _tables()


@functools.lru_cache(maxsize=None)
def _mul_table_flat():
    """Flat 64 KiB product table MUL[a*256+b] = a⊗b (§Perf optimization:
    one gather per (k, element) instead of two log gathers + a zero mask —
    measured +32% over the log/exp kernel under interpret-mode CPU)."""
    log, exp = _tables()
    a = np.arange(256)
    la = log[a]
    tab = exp[(la[:, None] + la[None, :]) % 255].astype(np.uint8)
    tab[0, :] = 0
    tab[:, 0] = 0
    return tab.reshape(-1)


# ---------------------------------------------------------------- kernel


def _gf_matmul_kernel(coeff_ref, data_ref, mul_ref, out_ref, *, k):
    """One grid step: out tile (R, TB) = GF-matmul(coeff (R,K), data tile).

    The flat product table arrives as a VMEM-resident input; the
    K-reduction is a ``fori_loop`` with one gather per step, so the live
    working set is one (R, TB) tile plus the 64 KiB table — small enough
    to double-buffer on real hardware. (§Perf iteration log: log/exp pair
    of gathers → single flat-table gather, +32% under interpret-mode.)
    """
    coeff = coeff_ref[...]  # (R, K) u8
    data = data_ref[...]  # (K, TB) u8
    mul_tab = mul_ref[...]  # (65536,) u8
    r_dim = coeff.shape[0]
    tb = data.shape[1]

    def body(i, acc):
        idx = coeff[:, i].astype(jnp.int32)[:, None] * 256
        idx = idx + data[i, :].astype(jnp.int32)[None, :]
        return acc ^ mul_tab[idx]

    out_ref[...] = lax.fori_loop(0, k, body, jnp.zeros((r_dim, tb), jnp.uint8))


def gf_matmul(coeff, data, *, tile_b=None):
    """``out[R,B] = Σ_k coeff[R,k] ⊗ data[k,B]`` over GF(2^8), via Pallas.

    Args:
      coeff: (R, K) uint8 coefficient matrix.
      data:  (K, B) uint8 payload (columns are byte positions).
      tile_b: byte-axis tile width (defaults to min(B, 8192); must divide B).

    Returns:
      (R, B) uint8.
    """
    r_dim, k = coeff.shape
    k2, b = data.shape
    assert k == k2, f"coeff K={k} vs data K={k2}"
    if tile_b is None:
        tile_b = min(b, 32768)
    assert b % tile_b == 0, f"tile_b={tile_b} must divide B={b}"
    mul_tab = jnp.asarray(_mul_table_flat())

    grid = (b // tile_b,)
    return pl.pallas_call(
        functools.partial(_gf_matmul_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((r_dim, b), jnp.uint8),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r_dim, k), lambda i: (0, 0)),  # coeff: resident
            pl.BlockSpec((k, tile_b), lambda i: (0, i)),  # data: streamed
            pl.BlockSpec((65536,), lambda i: (0,)),  # product table: resident
        ],
        out_specs=pl.BlockSpec((r_dim, tile_b), lambda i: (0, i)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(coeff, data, mul_tab)


def vmem_footprint_bytes(r_dim, k, tile_b):
    """Estimated VMEM working set per grid step (see §Perf): coefficient
    matrix + the 64 KiB product table + one data tile + one out tile +
    the (R,TB) accumulator and int32 index temporary of the loop body."""
    tables = 65536
    resident = r_dim * k + tables
    stream = k * tile_b + r_dim * tile_b
    temps = r_dim * tile_b + 4 * r_dim * tile_b  # u8 acc + i32 idx
    return resident + stream + temps
