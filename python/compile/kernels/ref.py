"""Pure-jnp (and pure-numpy) oracles for the GF(2^8) matmul kernel.

Three independent implementations keep each other honest:

* :func:`gf_matmul_ref` -- vectorized jnp, same log/exp tables;
* :func:`gf_mul_np` / :func:`gf_matmul_np` -- bitwise "schoolbook"
  carry-less multiply in numpy, no tables at all (the ground truth the
  tables themselves are validated against);
* the Pallas kernel under test (``gf_matmul.gf_matmul``).
"""

import jax.numpy as jnp
import numpy as np

from .gf_matmul import POLY, gf_tables


def gf_matmul_ref(coeff, data):
    """Vectorized jnp reference: identical semantics to the kernel."""
    log_np, exp_np = gf_tables()
    log_tab = jnp.asarray(log_np)
    exp_tab = jnp.asarray(exp_np)
    coeff = jnp.asarray(coeff, jnp.uint8)
    data = jnp.asarray(data, jnp.uint8)
    lc = log_tab[coeff.astype(jnp.int32)]  # (R, K)
    ld = log_tab[data.astype(jnp.int32)]  # (K, B)
    prod = exp_tab[lc[:, :, None] + ld[None, :, :]]  # (R, K, B)
    nz = (coeff[:, :, None] != 0) & (data[None, :, :] != 0)
    prod = jnp.where(nz, prod, jnp.uint8(0))
    # XOR-reduce over K
    out = prod[:, 0, :]
    for i in range(1, prod.shape[1]):
        out = out ^ prod[:, i, :]
    return out


def gf_mul_np(a, b):
    """Carry-less multiply mod POLY, elementwise over uint8 arrays."""
    a = np.asarray(a, dtype=np.uint16)
    b = np.asarray(b, dtype=np.uint16)
    a, b = np.broadcast_arrays(a, b)
    a = a.copy()
    b = b.copy()
    r = np.zeros_like(a)
    for _ in range(8):
        r ^= np.where(b & 1, a, np.uint16(0))
        hi = a & 0x80
        a = (a << 1) & 0xFF
        a = a ^ np.where(hi, np.uint16(POLY & 0xFF), np.uint16(0))
        b >>= 1
    return r.astype(np.uint8)


def gf_matmul_np(coeff, data):
    """Schoolbook GF matmul in numpy (slow; ground truth)."""
    coeff = np.asarray(coeff, dtype=np.uint8)
    data = np.asarray(data, dtype=np.uint8)
    r_dim, k = coeff.shape
    _, b = data.shape
    out = np.zeros((r_dim, b), dtype=np.uint8)
    for i in range(k):
        out ^= gf_mul_np(coeff[:, i][:, None], data[i, :][None, :])
    return out
