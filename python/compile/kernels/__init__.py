"""L1 Pallas kernels: the GF(2^8) matmul hot-spot plus its oracles."""

from .gf_matmul import gf_matmul, gf_tables, vmem_footprint_bytes  # noqa: F401
from .ref import gf_matmul_np, gf_matmul_ref, gf_mul_np  # noqa: F401
