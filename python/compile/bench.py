"""Micro-benchmarks for the L1 kernel under interpret-mode CPU (§Perf).

Reports GiB/s for the Pallas kernel, the jnp reference, and (for context)
numpy memcpy — the practical ceiling on this path. Usage:

    python -m compile.bench [--quick]
"""

import argparse
import time

import jax
import numpy as np

from .kernels import gf_matmul, gf_matmul_ref


def _bench(fn, *args, reps=20):
    out = fn(*args)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(shapes, reps):
    rng = np.random.default_rng(0)
    for (r, k, b) in shapes:
        coeff = rng.integers(0, 256, (r, k), np.uint8)
        data = rng.integers(0, 256, (k, b), np.uint8)
        bytes_in = k * b

        jk = jax.jit(lambda c, d: gf_matmul(c, d))
        jr = jax.jit(lambda c, d: gf_matmul_ref(c, d))
        tk = _bench(jk, coeff, data, reps=reps)
        tr = _bench(jr, coeff, data, reps=reps)

        t0 = time.perf_counter()
        for _ in range(reps):
            _ = data.copy()
        tm = (time.perf_counter() - t0) / reps

        gib = bytes_in / 2**30
        print(
            f"(r={r:>3}, k={k:>3}, b={b:>6}):  pallas {gib/tk:6.3f} GiB/s   "
            f"jnp-ref {gib/tr:6.3f} GiB/s   memcpy {gib/tm:7.2f} GiB/s   "
            f"(kernel/ref ratio {tr/tk:4.2f}x)"
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    shapes = [(4, 32, 65536)] if args.quick else [
        (4, 24, 65536),
        (4, 32, 65536),
        (12, 96, 65536),
        (12, 128, 65536),
    ]
    run(shapes, reps=10 if args.quick else 20)


if __name__ == "__main__":
    main()
