"""Static performance analysis for the L1 kernel (§Perf, DESIGN.md §3):
VMEM footprint per grid step, HLO op census of the lowered module, and
the double-buffering feasibility check for the real-TPU estimate.

Usage: python -m compile.analyze [--envelope R K B]
"""

import argparse
import re
from collections import Counter

from .aot import ENVELOPES, to_hlo_text
from .kernels import vmem_footprint_bytes
from .model import encode_lowered

VMEM_BYTES = 16 * 1024 * 1024  # one TPU core's VMEM


def op_census(hlo_text: str) -> Counter:
    """Count HLO opcodes in the module's entry + nested computations."""
    ops = Counter()
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?[%\w.\-]+\s*=\s*[^ ]+\s+([a-z0-9\-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
    return ops


def analyze(r, k, b, tile=32768):
    text = to_hlo_text(encode_lowered(r, k, b))
    fp = vmem_footprint_bytes(r, k, min(tile, b))
    ops = op_census(text)
    print(f"== envelope r{r}_k{k}_b{b} (tile {min(tile, b)}) ==")
    print(f"HLO text: {len(text)} chars; entry layout u8[{r},{k}] x u8[{k},{b}] -> u8[{r},{b}]")
    print(f"VMEM working set / grid step: {fp / 1024 / 1024:.2f} MiB "
          f"({fp / VMEM_BYTES * 100:.1f}% of 16 MiB)")
    db = fp + k * min(tile, b)  # + one in-flight streamed tile
    print(f"with double-buffered data tile: {db / 1024 / 1024:.2f} MiB "
          f"-> double buffering {'FITS' if db < VMEM_BYTES else 'DOES NOT FIT'}")
    interesting = {o: c for o, c in ops.items()
                   if o in ("gather", "while", "xor", "fusion", "dynamic-update-slice",
                            "dynamic-slice", "broadcast", "constant")}
    print(f"HLO op census (selected): {interesting}")
    gathers = ops.get("gather", 0)
    print(f"gathers per module: {gathers} (roofline driver on both CPU-interpret and TPU-VPU)")
    print()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--envelope", nargs=3, type=int, metavar=("R", "K", "B"))
    args = ap.parse_args()
    envs = [tuple(args.envelope)] if args.envelope else ENVELOPES
    for (r, k, b) in envs:
        analyze(r, k, b)


if __name__ == "__main__":
    main()
