"""L2 correctness: the stripe-codec graph composes encode and decode
through the same kernel, and the CP cascade identity holds end to end."""

import numpy as np
from compile.kernels import gf_matmul_np
from compile.kernels.gf_matmul import gf_tables
from compile.model import encode_fn, gf_inv_np, stripe_roundtrip


def cauchy_generator(k, r):
    """Systematic generator with Cauchy parity rows (matches the Rust
    codes::construct::base_generator)."""
    log, exp = gf_tables()

    def inv(x):
        return exp[(255 - log[x]) % 255]

    g = np.zeros((k + r, k), np.uint8)
    g[:k] = np.eye(k, dtype=np.uint8)
    for j in range(r):
        for i in range(k):
            g[k + j, i] = inv(i ^ (k + j))
    return g


def cp_azure_generator(k, r, p):
    """CP-Azure generator: local parity rows decompose the last global's
    coefficients (eq. (6))."""
    g = cauchy_generator(k, r)
    gsz = k // p
    rows = [g]
    for j in range(p):
        row = np.zeros((1, k), np.uint8)
        row[0, j * gsz:(j + 1) * gsz] = g[k + r - 1, j * gsz:(j + 1) * gsz]
        rows.append(row)
    return np.concatenate(rows, axis=0)


def test_encode_fn_is_gf_matmul():
    rng = np.random.default_rng(0)
    coeff = rng.integers(0, 256, (3, 6), np.uint8)
    data = rng.integers(0, 256, (6, 512), np.uint8)
    (out,) = encode_fn(coeff, data)
    assert (np.asarray(out) == gf_matmul_np(coeff, data)).all()


def test_gf_inv_np_roundtrip():
    rng = np.random.default_rng(1)
    for n in [1, 3, 6]:
        m = rng.integers(0, 256, (n, n), np.uint8)
        if np.linalg.matrix_rank(m.astype(float)) < n:  # cheap pre-filter only
            continue
        try:
            inv = gf_inv_np(m)
        except StopIteration:
            continue  # singular over GF(256)
        assert (gf_matmul_np(m, inv) == np.eye(n, dtype=np.uint8)).all()


def test_stripe_roundtrip_mds():
    k, r = 6, 2
    gen = cauchy_generator(k, r)
    rng = np.random.default_rng(2)
    data = rng.integers(0, 256, (k, 2048), np.uint8)
    # erase D0 and G1, keep D1..D5 + G0
    stripe, rec = stripe_roundtrip(gen, data, erase=[0, 7], keep=[1, 2, 3, 4, 5, 6])
    assert (rec[0] == stripe[0]).all()
    assert (rec[1] == stripe[7]).all()


def test_stripe_roundtrip_cp_azure_cascade():
    k, r, p = 6, 2, 2
    gen = cp_azure_generator(k, r, p)
    rng = np.random.default_rng(3)
    data = rng.integers(0, 256, (k, 1024), np.uint8)
    stripe = gf_matmul_np(gen, data)
    # cascade identity: L1 ^ L2 == G2
    assert (np.bitwise_xor(stripe[8], stripe[9]) == stripe[7]).all()
    # decode D0,D1 from survivors incl. local parities
    _, rec = stripe_roundtrip(gen, data, erase=[0, 1], keep=[2, 3, 4, 5, 6, 8])
    assert (rec[0] == stripe[0]).all()
    assert (rec[1] == stripe[1]).all()
