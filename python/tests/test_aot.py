"""AOT pipeline: artifacts are deterministic, parseable, carry their full
constant tables, and cover the paper's parameter envelope."""

import os
import tempfile

from compile.aot import ENVELOPES, build, to_hlo_text
from compile.model import encode_lowered


def test_envelopes_cover_p1_to_p8():
    params = [(6,2,2),(12,2,2),(16,3,2),(20,3,5),(24,2,2),(48,4,3),(72,4,4),(96,5,4)]
    for (k, r, p) in params:
        assert any(k <= ke and r + p <= re for (re, ke, _) in ENVELOPES), (k, r, p)


def test_hlo_text_contains_full_tables():
    text = to_hlo_text(encode_lowered(2, 4, 256))
    assert "{...}" not in text, "large constants were elided"
    assert "u8[65536]" in text  # flat product table
    assert "ENTRY" in text


def test_build_is_deterministic_and_named_right():
    with tempfile.TemporaryDirectory() as d:
        w1 = build(d, envelopes=[(2, 4, 512)], verbose=False)
        (path, size, digest) = w1[0]
        assert os.path.basename(path) == "gf_matmul_r2_k4_b512.hlo.txt"
        assert size > 1000
        w2 = build(d, envelopes=[(2, 4, 512)], verbose=False)
        assert w2[0][2] == digest, "artifact generation must be deterministic"


def test_entry_layout_mentions_shapes():
    text = to_hlo_text(encode_lowered(4, 32, 1024))
    assert "u8[4,32]" in text
    assert "u8[32,1024]" in text
    assert "u8[4,1024]" in text
