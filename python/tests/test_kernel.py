"""L1 correctness: the Pallas GF(2^8) matmul kernel vs two independent
oracles (vectorized jnp with the same tables; table-free numpy bitwise
multiply), with hypothesis sweeping shapes and contents."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    gf_matmul,
    gf_matmul_np,
    gf_matmul_ref,
    gf_mul_np,
    gf_tables,
    vmem_footprint_bytes,
)


def rand(shape, seed, nonzero=False):
    rng = np.random.default_rng(seed)
    lo = 1 if nonzero else 0
    return rng.integers(lo, 256, shape, dtype=np.uint8)


# ------------------------------------------------------------- tables

def test_tables_match_bitwise_multiply():
    log, exp = gf_tables()
    a = np.arange(256, dtype=np.uint8)
    for b in [1, 2, 3, 29, 255]:
        via_tables = np.where(
            (a != 0) & (b != 0),
            exp[log[a] + log[np.uint8(b)]],
            0,
        ).astype(np.uint8)
        assert (via_tables == gf_mul_np(a, b)).all()


def test_exp_table_doubled():
    _, exp = gf_tables()
    assert (exp[255:510] == exp[0:255]).all()


def test_gf_mul_np_field_axioms_sampled():
    rng = np.random.default_rng(7)
    a = rng.integers(0, 256, 4096, dtype=np.uint8)
    b = rng.integers(0, 256, 4096, dtype=np.uint8)
    c = rng.integers(0, 256, 4096, dtype=np.uint8)
    assert (gf_mul_np(a, b) == gf_mul_np(b, a)).all()
    assert (gf_mul_np(gf_mul_np(a, b), c) == gf_mul_np(a, gf_mul_np(b, c))).all()
    assert (gf_mul_np(a, np.uint8(1)) == a).all()
    # distributivity over XOR
    assert (gf_mul_np(a, b ^ c) == (gf_mul_np(a, b) ^ gf_mul_np(a, c))).all()


# ------------------------------------------------------------- kernel

@pytest.mark.parametrize(
    "r,k,b,tile",
    [
        (1, 1, 8, 8),
        (2, 4, 256, 128),
        (4, 24, 1024, 256),
        (4, 32, 8192, None),
        (12, 96, 4096, 1024),
        (9, 96, 2048, None),
    ],
)
def test_kernel_matches_oracles(r, k, b, tile):
    coeff = rand((r, k), seed=r * 100 + k)
    data = rand((k, b), seed=k * 7 + b)
    out = np.asarray(gf_matmul(coeff, data, tile_b=tile))
    assert (out == np.asarray(gf_matmul_ref(coeff, data))).all()
    assert (out == gf_matmul_np(coeff, data)).all()


def test_kernel_zero_coeff_rows_give_zero():
    coeff = np.zeros((3, 8), np.uint8)
    data = rand((8, 512), seed=1)
    assert (np.asarray(gf_matmul(coeff, data)) == 0).all()


def test_kernel_identity_coeff_passthrough():
    k = 8
    coeff = np.eye(k, dtype=np.uint8)
    data = rand((k, 256), seed=2)
    assert (np.asarray(gf_matmul(coeff, data)) == data).all()


def test_kernel_linearity():
    # gf_matmul(c, x ^ y) == gf_matmul(c, x) ^ gf_matmul(c, y)
    coeff = rand((4, 8), seed=3)
    x = rand((8, 512), seed=4)
    y = rand((8, 512), seed=5)
    lhs = np.asarray(gf_matmul(coeff, x ^ y))
    rhs = np.asarray(gf_matmul(coeff, x)) ^ np.asarray(gf_matmul(coeff, y))
    assert (lhs == rhs).all()


@settings(max_examples=40, deadline=None)
@given(
    r=st.integers(1, 8),
    k=st.integers(1, 32),
    tiles=st.integers(1, 4),
    tile=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis_sweep(r, k, tiles, tile, seed):
    b = tiles * tile
    coeff = rand((r, k), seed=seed)
    data = rand((k, b), seed=seed ^ 0xFFFF)
    out = np.asarray(gf_matmul(coeff, data, tile_b=tile))
    assert (out == gf_matmul_np(coeff, data)).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_cauchy_coefficients(seed):
    # the coefficients the codec actually uses: Cauchy rows
    log, exp = gf_tables()

    def inv(x):
        return exp[(255 - log[x]) % 255]

    k, r = 6, 2
    coeff = np.zeros((r, k), np.uint8)
    for j in range(r):
        for i in range(k):
            coeff[j, i] = inv(i ^ (k + j))
    data = rand((k, 1024), seed=seed)
    assert (np.asarray(gf_matmul(coeff, data)) == gf_matmul_np(coeff, data)).all()


def test_vmem_footprint_within_budget():
    # The wide envelope's working set must fit a TPU core's ~16 MiB VMEM
    # with room for double buffering (DESIGN.md §Hardware-Adaptation).
    fp = vmem_footprint_bytes(12, 128, 8192)
    assert fp < 4 * 1024 * 1024, f"footprint {fp} too large for double-buffering"
