//! Repo-local verification tasks: `cargo xtask lint` and
//! `cargo xtask prove`.
//!
//! The lint pass encodes this repository's safety and pinning
//! invariants as *source-level* checks (documented in VERIFICATION.md):
//!
//! 1. **Unsafe boundary** — the `unsafe` keyword is forbidden outside
//!    the kernel allowlist modules (`rust/src/gf/`,
//!    `rust/src/runtime/pjrt.rs`). The compiler enforces the same
//!    boundary via the crate's `unsafe_code = "deny"` lint table; this
//!    pass additionally covers examples, benches and integration tests
//!    (separate crates the lib-level lint table does not reach).
//! 2. **SAFETY comments** — inside the allowlist, every `unsafe fn` /
//!    `unsafe {}` site must carry a `// SAFETY:` comment on the same
//!    line or in the contiguous comment/attribute block above it.
//! 3. **Kernel registry** — every `#[target_feature]` kernel must have
//!    an entry in `rust/src/gf/kernel_registry.rs` whose feature string
//!    matches the attribute, whose dispatch seam exists and references
//!    the kernel, and whose named scalar-pinning test exists. A new
//!    kernel tier therefore cannot ship undispatched or unpinned.
//! 4. **Bench schemas** — every section key of the committed
//!    `BENCH_*.json` documents must be emitted by some bench source, so
//!    a schema cannot drift away from the benches that fill it.
//! 5. **Dependency audit** — the manifests may not grow dependencies
//!    beyond the committed allowlist (`anyhow`); the `cargo deny`-style
//!    audit this single-dependency tree actually needs.
//! 6. **w16 entry-point registry** — every top-level `pub fn` of the
//!    GF(2^16) surface (`rust/src/gf/w16.rs`) must appear in the
//!    registry's `W16_ENTRY_POINTS` table with a scalar-pinning test
//!    that exists, so the ultra-wide-stripe substrate cannot grow an
//!    unpinned entry point.
//!
//! `cargo xtask prove` runs the **proof plane** (VERIFICATION.md
//! tier 6): the symbolic decodability prover, plan-optimality auditor
//! and schedule-space model checker that live in the main crate's
//! `verify` module. xtask stays dependency-free by delegating to
//! `cargo run --bin repro -- prove` with the `model-check` feature.
//!
//! Everything runs on plain `std` over the source text: a
//! length-preserving comment/string stripper feeds token-level scans,
//! so keywords in strings or comments never false-positive. Each check
//! is a pure function over `(path, contents)` pairs; the self-tests
//! below seed one violation of every class and assert it is caught.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files (by repo-relative prefix) allowed to contain `unsafe`.
const UNSAFE_ALLOWLIST: &[&str] = &["rust/src/gf/", "rust/src/runtime/pjrt.rs"];

/// Path of the machine-readable kernel registry.
const REGISTRY_PATH: &str = "rust/src/gf/kernel_registry.rs";

/// Path of the GF(2^16) surface covered by the `W16_ENTRY_POINTS`
/// registry table.
const W16_PATH: &str = "rust/src/gf/w16.rs";

/// The only crates any manifest in this workspace may depend on.
const ALLOWED_DEPENDENCIES: &[&str] = &["anyhow"];

/// One lint finding.
struct Diag {
    path: String,
    line: usize,
    msg: String,
}

impl Diag {
    fn new(path: &str, line: usize, msg: String) -> Self {
        Self { path: path.to_string(), line, msg }
    }
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line > 0 {
            write!(f, "{}:{}: {}", self.path, self.line, self.msg)
        } else {
            write!(f, "{}: {}", self.path, self.msg)
        }
    }
}

/// `(repo-relative path with forward slashes, file contents)`.
type Source = (String, String);

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") | None => {}
        Some("prove") => return prove(),
        Some(other) => {
            eprintln!("unknown xtask command `{other}` (available: lint, prove)");
            return ExitCode::FAILURE;
        }
    }
    let root = repo_root();
    let diags = match lint_tree(&root) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("xtask lint: {e}");
            return ExitCode::FAILURE;
        }
    };
    if diags.is_empty() {
        println!("xtask lint: clean");
        ExitCode::SUCCESS
    } else {
        for d in &diags {
            eprintln!("{d}");
        }
        eprintln!("xtask lint: {} finding(s)", diags.len());
        ExitCode::FAILURE
    }
}

/// `cargo xtask prove`: run the proof plane. The analyses live in the
/// main crate (`cp_lrc::verify`, std + anyhow only); xtask stays
/// dependency-free by shelling out to the repro binary with the
/// `model-check` feature, so the schedule-space checker is compiled in.
fn prove() -> ExitCode {
    let cargo = std::env::var("CARGO").unwrap_or_else(|_| "cargo".to_string());
    let status = std::process::Command::new(cargo)
        .args([
            "run",
            "--release",
            "--features",
            "model-check",
            "--bin",
            "repro",
            "--",
            "prove",
        ])
        .current_dir(repo_root())
        .status();
    match status {
        Ok(s) if s.success() => ExitCode::SUCCESS,
        Ok(_) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("xtask prove: failed to launch cargo: {e}");
            ExitCode::FAILURE
        }
    }
}

/// The repository root: the parent of this crate's manifest directory.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level under the repo root")
        .to_path_buf()
}

/// Gather inputs from disk and run every check.
fn lint_tree(root: &Path) -> Result<Vec<Diag>, String> {
    let mut sources: Vec<Source> = Vec::new();
    for dir in ["rust", "examples", "xtask"] {
        collect_rs(&root.join(dir), root, &mut sources)?;
    }
    sources.sort();

    let mut schemas: Vec<Source> = Vec::new();
    let entries =
        fs::read_dir(root).map_err(|e| format!("read_dir {}: {e}", root.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            let text = fs::read_to_string(entry.path()).map_err(|e| format!("{name}: {e}"))?;
            schemas.push((name, text));
        }
    }
    schemas.sort();

    let mut manifests: Vec<Source> = Vec::new();
    for m in ["Cargo.toml", "xtask/Cargo.toml"] {
        let text =
            fs::read_to_string(root.join(m)).map_err(|e| format!("{m}: {e}"))?;
        manifests.push((m.to_string(), text));
    }

    let bench_sources: Vec<Source> = sources
        .iter()
        .filter(|(p, _)| p.starts_with("rust/benches/"))
        .cloned()
        .collect();

    let mut diags = check_unsafe_boundary(&sources);
    diags.extend(check_kernel_registry(&sources));
    diags.extend(check_w16_registry(&sources));
    diags.extend(check_bench_schemas(&schemas, &bench_sources));
    diags.extend(check_dependency_audit(&manifests));
    Ok(diags)
}

/// Recursively collect `.rs` files under `dir` as repo-relative sources.
fn collect_rs(dir: &Path, root: &Path, out: &mut Vec<Source>) -> Result<(), String> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // optional directory
    };
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let path = entry.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            collect_rs(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .map_err(|e| e.to_string())?
                .to_string_lossy()
                .replace('\\', "/");
            let text =
                fs::read_to_string(&path).map_err(|e| format!("{rel}: {e}"))?;
            out.push((rel, text));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Source-text substrate: a length-preserving stripper + token scans.
// ---------------------------------------------------------------------

fn ident_char(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Blank out comments and string/char-literal contents with spaces,
/// preserving every byte offset and newline, so token scans over the
/// result never match inside prose. Handles nested block comments,
/// escaped strings, byte strings, raw strings of any `#` depth, and
/// char literals (lifetimes are left intact).
fn strip_comments_and_strings(src: &str) -> String {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = b.to_vec();
    let blank = |out: &mut [u8], lo: usize, hi: usize| {
        for slot in out[lo..hi.min(n)].iter_mut() {
            if *slot != b'\n' {
                *slot = b' ';
            }
        }
    };
    let mut i = 0;
    while i < n {
        match b[i] {
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                let mut j = i;
                while j < n && b[j] != b'\n' {
                    j += 1;
                }
                blank(&mut out, i, j);
                i = j;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if j + 1 < n && b[j] == b'/' && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if j + 1 < n && b[j] == b'*' && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, i, j);
                i = j;
            }
            b'r' | b'b' if raw_string_hashes(b, i).is_some() => {
                let (hashes, open) = raw_string_hashes(b, i).expect("guard");
                let close = raw_string_end(b, open, hashes);
                blank(&mut out, open + 1, close);
                i = close + 1 + hashes;
            }
            b'"' => {
                let mut j = i + 1;
                while j < n {
                    match b[j] {
                        b'\\' => j += 2,
                        b'"' => break,
                        _ => j += 1,
                    }
                }
                blank(&mut out, i + 1, j);
                i = (j + 1).min(n);
            }
            b'\'' => {
                if i + 1 < n && b[i + 1] == b'\\' {
                    // Escaped char literal: find the closing quote.
                    let mut j = i + 2;
                    while j < n && b[j] != b'\'' {
                        j += 1;
                    }
                    blank(&mut out, i + 1, j);
                    i = (j + 1).min(n);
                } else if i + 2 < n && b[i + 2] == b'\'' {
                    // Plain char literal 'x'.
                    blank(&mut out, i + 1, i + 2);
                    i += 3;
                } else {
                    i += 1; // lifetime or label
                }
            }
            _ => i += 1,
        }
    }
    String::from_utf8(out).expect("blanking preserves UTF-8")
}

/// If position `i` starts a raw string (`r"`, `r#"`, `br"`, ...) whose
/// `r` is not part of an identifier, return `(hash count, index of the
/// opening quote)`.
fn raw_string_hashes(b: &[u8], i: usize) -> Option<(usize, usize)> {
    if i > 0 && ident_char(b[i - 1]) {
        return None;
    }
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some((hashes, j))
    } else {
        None
    }
}

/// Index of the closing quote of a raw string opened at `open` with
/// `hashes` hash marks (or the end of input).
fn raw_string_end(b: &[u8], open: usize, hashes: usize) -> usize {
    let mut j = open + 1;
    while j < b.len() {
        if b[j] == b'"' && b[j + 1..].iter().take(hashes).filter(|&&c| c == b'#').count() == hashes
        {
            return j;
        }
        j += 1;
    }
    b.len()
}

/// 1-based line numbers of every occurrence of keyword/identifier `kw`
/// in (stripped) source text, with word-boundary checks on both sides.
fn token_lines(stripped: &str, kw: &str) -> Vec<usize> {
    let sb = stripped.as_bytes();
    let mut lines = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < sb.len() {
        if sb[i] == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if stripped[i..].starts_with(kw) {
            let before_ok = i == 0 || !ident_char(sb[i - 1]);
            let after = i + kw.len();
            let after_ok = after >= sb.len() || !ident_char(sb[after]);
            if before_ok && after_ok {
                lines.push(line);
                i = after;
                continue;
            }
        }
        i += 1;
    }
    lines
}

/// Count word-boundary occurrences of `ident` in (stripped) text.
fn ident_occurrences(stripped: &str, ident: &str) -> usize {
    token_lines(stripped, ident).len()
}

/// The source extent of top-level `fn name`: from its `fn` keyword to
/// the first close brace at column zero (rustfmt's item terminator).
fn fn_extent<'a>(stripped: &'a str, name: &str) -> Option<&'a str> {
    let sb = stripped.as_bytes();
    let needle = format!("fn {name}");
    let mut from = 0usize;
    while let Some(rel) = stripped[from..].find(&needle) {
        let at = from + rel;
        let before_ok = at == 0 || !ident_char(sb[at.saturating_sub(1)]);
        let after = at + needle.len();
        let after_ok = after >= sb.len() || !ident_char(sb[after]);
        if before_ok && after_ok {
            let end = stripped[at..]
                .find("\n}")
                .map(|p| at + p + 2)
                .unwrap_or(stripped.len());
            return Some(&stripped[at..end]);
        }
        from = after;
    }
    None
}

fn has_fn(stripped: &str, name: &str) -> bool {
    fn_extent(stripped, name).is_some()
}

// ---------------------------------------------------------------------
// Check 1 + 2: the unsafe boundary and SAFETY comments.
// ---------------------------------------------------------------------

fn allowlisted(path: &str) -> bool {
    UNSAFE_ALLOWLIST.iter().any(|p| path.starts_with(p))
}

/// Lines of `unsafe` sites in `src` with no `SAFETY:` comment on the
/// same line or in the contiguous comment/attribute block above.
fn missing_safety_comments(src: &str) -> Vec<usize> {
    let stripped = strip_comments_and_strings(src);
    let lines: Vec<&str> = src.lines().collect();
    let mut missing = Vec::new();
    for line in token_lines(&stripped, "unsafe") {
        let mut ok = lines.get(line - 1).is_some_and(|l| l.contains("SAFETY:"));
        let mut k = line - 1; // 1-based line above the unsafe site
        while !ok && k >= 1 {
            let l = lines[k - 1].trim_start();
            let scannable = l.is_empty()
                || l.starts_with("//")
                || l.starts_with("#[")
                || l.starts_with("#!")
                || l.starts_with('*');
            if !scannable {
                break;
            }
            if l.contains("SAFETY:") {
                ok = true;
            }
            k -= 1;
        }
        if !ok {
            missing.push(line);
        }
    }
    missing
}

fn check_unsafe_boundary(sources: &[Source]) -> Vec<Diag> {
    let mut diags = Vec::new();
    for (path, src) in sources {
        if allowlisted(path) {
            for line in missing_safety_comments(src) {
                diags.push(Diag::new(
                    path,
                    line,
                    "`unsafe` site without a `// SAFETY:` comment (same line or the \
                     comment/attribute block directly above)"
                        .to_string(),
                ));
            }
        } else {
            let stripped = strip_comments_and_strings(src);
            for line in token_lines(&stripped, "unsafe") {
                diags.push(Diag::new(
                    path,
                    line,
                    format!(
                        "`unsafe` outside the kernel allowlist ({}); move the code \
                         into an allowlisted module or make it safe",
                        UNSAFE_ALLOWLIST.join(", ")
                    ),
                ));
            }
        }
    }
    diags
}

// ---------------------------------------------------------------------
// Check 3: the kernel registry.
// ---------------------------------------------------------------------

#[derive(Debug, PartialEq, Eq)]
struct RegEntry {
    name: String,
    features: String,
    dispatch: String,
    pinning_test: String,
}

/// Parse `KernelEntry { name: "...", features: "...", dispatch: "...",
/// pinning_test: "..." }` records out of the registry source.
fn parse_registry(src: &str) -> Vec<RegEntry> {
    let field = |chunk: &str, name: &str| -> Option<String> {
        let at = chunk.find(&format!("{name}:"))?;
        let rest = &chunk[at..];
        let q1 = rest.find('"')?;
        let q2 = rest[q1 + 1..].find('"')?;
        Some(rest[q1 + 1..q1 + 1 + q2].to_string())
    };
    let mut entries = Vec::new();
    for chunk in src.split("KernelEntry {").skip(1) {
        let (Some(name), Some(features), Some(dispatch), Some(pinning_test)) = (
            field(chunk, "name"),
            field(chunk, "features"),
            field(chunk, "dispatch"),
            field(chunk, "pinning_test"),
        ) else {
            continue;
        };
        entries.push(RegEntry { name, features, dispatch, pinning_test });
    }
    entries
}

/// `(kernel name, feature string, 1-based line)` for every
/// `#[target_feature(enable = "...")]` function in `src`.
fn target_feature_kernels(src: &str) -> Vec<(String, String, usize)> {
    let stripped = strip_comments_and_strings(src);
    let sb = stripped.as_bytes();
    let mut found = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = stripped[from..].find("#[target_feature") {
        let at = from + rel;
        let line = stripped[..at].matches('\n').count() + 1;
        // The feature string sits in the original text (stripping
        // blanked it); offsets are identical by construction.
        let attr_end = stripped[at..].find(']').map(|p| at + p).unwrap_or(stripped.len());
        let features = src[at..attr_end]
            .split('"')
            .nth(1)
            .unwrap_or("")
            .to_string();
        // The kernel is the next `fn` token after the attribute.
        let mut name = String::new();
        if let Some(fn_rel) = stripped[attr_end..].find("fn ") {
            let mut j = attr_end + fn_rel + 3;
            while j < sb.len() && sb[j] == b' ' {
                j += 1;
            }
            while j < sb.len() && ident_char(sb[j]) {
                name.push(sb[j] as char);
                j += 1;
            }
        }
        found.push((name, features, line));
        from = attr_end;
    }
    found
}

fn check_kernel_registry(sources: &[Source]) -> Vec<Diag> {
    let mut diags = Vec::new();
    let Some((_, registry_src)) = sources.iter().find(|(p, _)| p == REGISTRY_PATH) else {
        diags.push(Diag::new(
            REGISTRY_PATH,
            0,
            "kernel registry is missing (every #[target_feature] kernel must be \
             declared here)"
                .to_string(),
        ));
        return diags;
    };
    let registry = parse_registry(registry_src);
    for (i, e) in registry.iter().enumerate() {
        if registry[..i].iter().any(|o| o.name == e.name) {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!("duplicate registry entry for kernel `{}`", e.name),
            ));
        }
    }

    // Stripped gf sources (registry excluded — its strings are data,
    // not code) and stripped everything (pinning tests may live in
    // integration suites).
    let gf_stripped: Vec<(String, String)> = sources
        .iter()
        .filter(|(p, _)| p.starts_with("rust/src/gf/") && p != REGISTRY_PATH)
        .map(|(p, s)| (p.clone(), strip_comments_and_strings(s)))
        .collect();
    let all_stripped: Vec<String> = sources
        .iter()
        .filter(|(p, _)| p != REGISTRY_PATH)
        .map(|(_, s)| strip_comments_and_strings(s))
        .collect();

    // Every #[target_feature] kernel in the tree must be registered,
    // with a matching feature string, and must live under gf.
    let mut discovered: Vec<(String, String)> = Vec::new();
    for (path, src) in sources {
        for (name, features, line) in target_feature_kernels(src) {
            if !path.starts_with("rust/src/gf/") {
                diags.push(Diag::new(
                    path,
                    line,
                    format!(
                        "#[target_feature] kernel `{name}` outside rust/src/gf/ — \
                         kernels live in the gf module so the registry and pinning \
                         conventions cover them"
                    ),
                ));
            }
            match registry.iter().find(|e| e.name == name) {
                None => diags.push(Diag::new(
                    path,
                    line,
                    format!(
                        "#[target_feature] kernel `{name}` is not in {REGISTRY_PATH} \
                         (register it with its dispatch seam and scalar-pinning test)"
                    ),
                )),
                Some(e) if e.features != features => diags.push(Diag::new(
                    path,
                    line,
                    format!(
                        "kernel `{name}` enables \"{features}\" but the registry \
                         declares \"{}\"",
                        e.features
                    ),
                )),
                Some(_) => {}
            }
            discovered.push((name, features));
        }
    }

    // Every registry entry must point at real code: the kernel exists,
    // the dispatch seam exists and references it, the pinning test
    // exists somewhere in the tree.
    for e in &registry {
        let kernel_exists = gf_stripped.iter().any(|(_, s)| has_fn(s, &e.name));
        if !kernel_exists {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!("registry entry `{}` names a kernel that does not exist", e.name),
            ));
            continue;
        }
        let mut dispatch_refs = false;
        let mut dispatch_exists = false;
        for (_, s) in &gf_stripped {
            if let Some(extent) = fn_extent(s, &e.dispatch) {
                dispatch_exists = true;
                if ident_occurrences(extent, &e.name) > 0 {
                    dispatch_refs = true;
                }
            }
        }
        if !dispatch_exists {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!(
                    "kernel `{}` declares dispatch seam `{}` which does not exist",
                    e.name, e.dispatch
                ),
            ));
        } else if !dispatch_refs {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!(
                    "dispatch seam `{}` never references kernel `{}` — the kernel \
                     would ship undispatched",
                    e.dispatch, e.name
                ),
            ));
        }
        if !all_stripped.iter().any(|s| has_fn(s, &e.pinning_test)) {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!(
                    "kernel `{}` declares pinning test `{}` which does not exist — \
                     the kernel would ship unpinned",
                    e.name, e.pinning_test
                ),
            ));
        }
    }
    diags
}

// ---------------------------------------------------------------------
// Check 6: the w16 entry-point registry.
// ---------------------------------------------------------------------

#[derive(Debug, PartialEq, Eq)]
struct W16Entry {
    name: String,
    pinning_test: String,
}

/// Parse `GfEntryPoint { name: "...", pinning_test: "..." }` records
/// out of the registry source.
fn parse_w16_registry(src: &str) -> Vec<W16Entry> {
    let field = |chunk: &str, name: &str| -> Option<String> {
        let at = chunk.find(&format!("{name}:"))?;
        let rest = &chunk[at..];
        let q1 = rest.find('"')?;
        let q2 = rest[q1 + 1..].find('"')?;
        Some(rest[q1 + 1..q1 + 1 + q2].to_string())
    };
    let mut entries = Vec::new();
    for chunk in src.split("GfEntryPoint {").skip(1) {
        let (Some(name), Some(pinning_test)) =
            (field(chunk, "name"), field(chunk, "pinning_test"))
        else {
            continue;
        };
        entries.push(W16Entry { name, pinning_test });
    }
    entries
}

/// Names of every **top-level** `pub fn` / `pub const fn` in (stripped)
/// source text — column-zero items only, so trait methods and nested
/// helpers don't count as entry points.
fn top_level_pub_fns(stripped: &str) -> Vec<String> {
    let mut names = Vec::new();
    for line in stripped.lines() {
        let rest = if let Some(r) = line.strip_prefix("pub fn ") {
            r
        } else if let Some(r) = line.strip_prefix("pub const fn ") {
            r
        } else {
            continue;
        };
        let name: String =
            rest.bytes().take_while(|&c| ident_char(c)).map(char::from).collect();
        if !name.is_empty() {
            names.push(name);
        }
    }
    names
}

/// Check 6: every top-level public GF(2^16) entry point must appear in
/// the registry's `W16_ENTRY_POINTS` table, every table row must name
/// an entry point and a pinning test that exist. Mirrors the kernel
/// registry's existence convention (check 3).
fn check_w16_registry(sources: &[Source]) -> Vec<Diag> {
    let mut diags = Vec::new();
    let Some((_, registry_src)) = sources.iter().find(|(p, _)| p == REGISTRY_PATH) else {
        // Check 3 already reports the missing registry.
        return diags;
    };
    let Some((_, w16_src)) = sources.iter().find(|(p, _)| p == W16_PATH) else {
        // No w16 surface in this tree (fixture runs): nothing to cover.
        return diags;
    };
    let registry = parse_w16_registry(registry_src);
    for (i, e) in registry.iter().enumerate() {
        if registry[..i].iter().any(|o| o.name == e.name) {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!("duplicate w16 registry entry for `{}`", e.name),
            ));
        }
    }

    let w16_stripped = strip_comments_and_strings(w16_src);
    let public = top_level_pub_fns(&w16_stripped);
    let all_stripped: Vec<String> = sources
        .iter()
        .filter(|(p, _)| p != REGISTRY_PATH)
        .map(|(_, s)| strip_comments_and_strings(s))
        .collect();

    for name in &public {
        if !registry.iter().any(|e| &e.name == name) {
            diags.push(Diag::new(
                W16_PATH,
                0,
                format!(
                    "public GF(2^16) entry point `{name}` is not in {REGISTRY_PATH}'s \
                     W16_ENTRY_POINTS (register it with its scalar-pinning test)"
                ),
            ));
        }
    }
    for e in &registry {
        if !public.iter().any(|n| n == &e.name) {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!(
                    "w16 registry entry `{}` names an entry point that does not exist",
                    e.name
                ),
            ));
            continue;
        }
        if !all_stripped.iter().any(|s| has_fn(s, &e.pinning_test)) {
            diags.push(Diag::new(
                REGISTRY_PATH,
                0,
                format!(
                    "w16 entry point `{}` declares pinning test `{}` which does not \
                     exist — the entry point would ship unpinned",
                    e.name, e.pinning_test
                ),
            ));
        }
    }
    diags
}

// ---------------------------------------------------------------------
// Check 4: bench schema keys.
// ---------------------------------------------------------------------

/// Top-level keys of the `"sections"` object in a BENCH_*.json schema.
fn bench_section_keys(json: &str) -> Vec<String> {
    let Some(at) = json.find("\"sections\"") else { return Vec::new() };
    let Some(open) = json[at..].find('{').map(|p| at + p) else { return Vec::new() };
    let b = json.as_bytes();
    let mut keys = Vec::new();
    let mut depth = 0i32;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'{' | b'[' => depth += 1,
            b'}' | b']' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            b'"' if depth == 1 => {
                let start = i + 1;
                let mut j = start;
                while j < b.len() && b[j] != b'"' {
                    j += if b[j] == b'\\' { 2 } else { 1 };
                }
                let key = &json[start..j.min(json.len())];
                let mut k = j + 1;
                while k < b.len() && (b[k] == b' ' || b[k] == b'\n') {
                    k += 1;
                }
                if k < b.len() && b[k] == b':' {
                    keys.push(key.to_string());
                }
                i = j;
            }
            _ => {}
        }
        i += 1;
    }
    keys
}

fn check_bench_schemas(schemas: &[Source], bench_sources: &[Source]) -> Vec<Diag> {
    let mut diags = Vec::new();
    for (name, json) in schemas {
        for key in bench_section_keys(json) {
            let quoted = format!("\"{key}\"");
            let emitted = bench_sources
                .iter()
                .any(|(_, src)| src.contains(&quoted) || src.contains(&format!("\\\"{key}\\\"")));
            if !emitted {
                diags.push(Diag::new(
                    name,
                    0,
                    format!(
                        "schema section \"{key}\" is not emitted by any bench under \
                         rust/benches/ — the committed schema would never be filled"
                    ),
                ));
            }
        }
    }
    diags
}

// ---------------------------------------------------------------------
// Check 5: dependency audit.
// ---------------------------------------------------------------------

/// Crate names declared in any `[dependencies]`-like section.
fn manifest_deps(manifest: &str) -> Vec<String> {
    let dep_sections =
        ["[dependencies]", "[dev-dependencies]", "[build-dependencies]"];
    let mut deps = Vec::new();
    let mut in_deps = false;
    for line in manifest.lines() {
        let t = line.trim();
        if t.starts_with('[') {
            in_deps = dep_sections.contains(&t);
            for prefix in ["[dependencies.", "[dev-dependencies.", "[build-dependencies."] {
                if let Some(rest) = t.strip_prefix(prefix) {
                    deps.push(rest.trim_end_matches(']').to_string());
                }
            }
            continue;
        }
        if in_deps && !t.is_empty() && !t.starts_with('#') {
            if let Some(eq) = t.find('=') {
                deps.push(t[..eq].trim().to_string());
            }
        }
    }
    deps
}

fn check_dependency_audit(manifests: &[Source]) -> Vec<Diag> {
    let mut diags = Vec::new();
    for (path, manifest) in manifests {
        for dep in manifest_deps(manifest) {
            if !ALLOWED_DEPENDENCIES.contains(&dep.as_str()) {
                diags.push(Diag::new(
                    path,
                    0,
                    format!(
                        "dependency `{dep}` is outside the allowlist ({}); this tree \
                         builds offline from std + the allowlist only",
                        ALLOWED_DEPENDENCIES.join(", ")
                    ),
                ));
            }
        }
    }
    diags
}

// ---------------------------------------------------------------------
// Self-tests: each violation class is seeded and must be caught, and
// the real tree must be clean.
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn src(path: &str, text: &str) -> Source {
        (path.to_string(), text.to_string())
    }

    #[test]
    fn stripper_blanks_comments_strings_and_chars() {
        let input =
            "let a = \"unsafe\"; // unsafe\nlet b = 'u'; /* unsafe */ let c = r#\"unsafe\"#;";
        let s = strip_comments_and_strings(input);
        assert_eq!(ident_occurrences(&s, "unsafe"), 0);
        assert_eq!(s.len(), input.len(), "stripping must preserve byte offsets");
        let t = strip_comments_and_strings("let x = '\\n'; let l: &'static str = \"y\";");
        assert_eq!(ident_occurrences(&t, "static"), 1, "lifetimes survive stripping");
    }

    #[test]
    fn token_scan_respects_word_boundaries() {
        let s = "unsafe_code deny(unsafe_code) unsafe fn f() {} my_unsafe";
        assert_eq!(token_lines(s, "unsafe"), vec![1]);
    }

    #[test]
    fn seeded_unsafe_outside_allowlist_is_caught() {
        let bad = src(
            "rust/src/netsim/mod.rs",
            "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
        );
        let diags = check_unsafe_boundary(&[bad]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].line, 2);
        // The same code inside the allowlist (with a SAFETY comment) is fine.
        let ok = src(
            "rust/src/gf/mod.rs",
            "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller contract.\n    unsafe { *p }\n}\n",
        );
        assert!(check_unsafe_boundary(&[ok]).is_empty());
    }

    #[test]
    fn seeded_missing_safety_comment_is_caught() {
        let bad = src(
            "rust/src/gf/mod.rs",
            "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
        );
        let diags = check_unsafe_boundary(&[bad]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].msg.contains("SAFETY"));
        // A SAFETY comment above attributes, doc comments or on the same
        // line all satisfy the convention.
        let ok = src(
            "rust/src/gf/mod.rs",
            "// SAFETY: feature-checked by the dispatch seam.\n#[target_feature(enable = \"avx2\")]\nunsafe fn k() {}\n\nfn g() { let x = unsafe { 1 }; // SAFETY: trivially fine.\n}\n",
        );
        let diags = check_unsafe_boundary(&[ok]);
        let safety: Vec<_> =
            diags.iter().filter(|d| d.msg.contains("SAFETY")).collect();
        assert!(safety.is_empty(), "{safety:?}");
    }

    const REGISTRY_FIXTURE: &str = r#"
pub const KERNELS: &[KernelEntry] = &[
    KernelEntry {
        name: "kern_a",
        features: "avx2",
        dispatch: "disp",
        pinning_test: "kern_a_pinned_to_scalar",
    },
];
"#;

    fn gf_fixture() -> Vec<Source> {
        vec![
            src(
                "rust/src/gf/mod.rs",
                "#[target_feature(enable = \"avx2\")]\n// SAFETY: test fixture.\nunsafe fn kern_a() {}\n\nfn disp() {\n    // SAFETY: test fixture.\n    unsafe { kern_a() }\n}\n\n#[test]\nfn kern_a_pinned_to_scalar() {\n}\n",
            ),
            src("rust/src/gf/kernel_registry.rs", REGISTRY_FIXTURE),
        ]
    }

    #[test]
    fn registered_dispatched_pinned_kernel_is_clean() {
        let diags = check_kernel_registry(&gf_fixture());
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn seeded_unregistered_kernel_is_caught() {
        let mut sources = gf_fixture();
        sources[0].1.push_str(
            "\n#[target_feature(enable = \"gfni,avx2\")]\n// SAFETY: test fixture.\nunsafe fn kern_b() {}\n",
        );
        let diags = check_kernel_registry(&sources);
        assert!(
            diags.iter().any(|d| d.msg.contains("kern_b") && d.msg.contains("not in")),
            "{diags:?}"
        );
    }

    #[test]
    fn seeded_feature_string_mismatch_is_caught() {
        let mut sources = gf_fixture();
        sources[0].1 = sources[0].1.replace("enable = \"avx2\"", "enable = \"avx512f\"");
        let diags = check_kernel_registry(&sources);
        assert!(diags.iter().any(|d| d.msg.contains("declares \"avx2\"")), "{diags:?}");
    }

    #[test]
    fn seeded_undispatched_kernel_is_caught() {
        let mut sources = gf_fixture();
        // The dispatch seam exists but no longer references the kernel.
        sources[0].1 = sources[0]
            .1
            .replace("unsafe { kern_a() }", "unsafe { std::hint::black_box(0) };");
        let diags = check_kernel_registry(&sources);
        assert!(diags.iter().any(|d| d.msg.contains("undispatched")), "{diags:?}");
    }

    #[test]
    fn seeded_unpinned_kernel_is_caught() {
        let mut sources = gf_fixture();
        sources[0].1 = sources[0].1.replace("fn kern_a_pinned_to_scalar", "fn renamed_test");
        let diags = check_kernel_registry(&sources);
        assert!(diags.iter().any(|d| d.msg.contains("unpinned")), "{diags:?}");
    }

    #[test]
    fn seeded_phantom_registry_entry_is_caught() {
        let mut sources = gf_fixture();
        sources[0].1 = sources[0].1.replace("unsafe fn kern_a", "unsafe fn kern_z");
        let diags = check_kernel_registry(&sources);
        assert!(diags.iter().any(|d| d.msg.contains("does not exist")), "{diags:?}");
    }

    const W16_REGISTRY_FIXTURE: &str = r#"
pub const W16_ENTRY_POINTS: &[GfEntryPoint] = &[
    GfEntryPoint { name: "mul16", pinning_test: "mul16_pinned_to_slow" },
];
"#;

    fn w16_fixture() -> Vec<Source> {
        vec![
            src(
                "rust/src/gf/w16.rs",
                "pub fn mul16(a: u16, b: u16) -> u16 {\n    a ^ b\n}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn mul16_pinned_to_slow() {\n    }\n}\n",
            ),
            src("rust/src/gf/kernel_registry.rs", W16_REGISTRY_FIXTURE),
        ]
    }

    #[test]
    fn registered_pinned_w16_surface_is_clean() {
        let diags = check_w16_registry(&w16_fixture());
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn seeded_unregistered_w16_entry_point_is_caught() {
        let mut sources = w16_fixture();
        sources[0]
            .1
            .push_str("\npub const fn inv16(a: u16) -> u16 {\n    a\n}\n");
        let diags = check_w16_registry(&sources);
        assert!(
            diags.iter().any(|d| d.msg.contains("inv16") && d.msg.contains("not in")),
            "{diags:?}"
        );
    }

    #[test]
    fn seeded_unpinned_w16_entry_point_is_caught() {
        let mut sources = w16_fixture();
        sources[0].1 = sources[0].1.replace("fn mul16_pinned_to_slow", "fn renamed_test");
        let diags = check_w16_registry(&sources);
        assert!(diags.iter().any(|d| d.msg.contains("unpinned")), "{diags:?}");
    }

    #[test]
    fn seeded_phantom_w16_registry_entry_is_caught() {
        let mut sources = w16_fixture();
        sources[0].1 = sources[0].1.replace("pub fn mul16", "pub fn mul16_renamed");
        let diags = check_w16_registry(&sources);
        assert!(diags.iter().any(|d| d.msg.contains("does not exist")), "{diags:?}");
    }

    #[test]
    fn nested_and_method_fns_are_not_w16_entry_points() {
        let mut sources = w16_fixture();
        sources[0].1.push_str(
            "\npub struct T16;\n\nimpl T16 {\n    pub fn method(&self) -> u16 {\n        0\n    }\n}\n",
        );
        let diags = check_w16_registry(&sources);
        assert!(diags.is_empty(), "column-indented fns are not entry points: {diags:?}");
    }

    #[test]
    fn seeded_unemitted_bench_schema_key_is_caught() {
        let schema = src(
            "BENCH_x.json",
            r#"{ "bench": "x", "sections": { "real_section": [], "phantom_section": [] } }"#,
        );
        let bench = src(
            "rust/benches/x.rs",
            "fn main() { println!(\"{}\", \"\\\"real_section\\\"\"); }",
        );
        let diags = check_bench_schemas(&[schema], &[bench]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].msg.contains("phantom_section"));
    }

    #[test]
    fn seeded_dependency_outside_allowlist_is_caught() {
        let bad = src(
            "Cargo.toml",
            "[package]\nname = \"x\"\n\n[dependencies]\nanyhow = \"1\"\nserde = \"1\"\n\n[features]\npjrt = []\n",
        );
        let diags = check_dependency_audit(&[bad]);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert!(diags[0].msg.contains("serde"));
    }

    #[test]
    fn repo_tree_is_clean() {
        let diags = lint_tree(&repo_root()).expect("lint inputs readable");
        assert!(
            diags.is_empty(),
            "xtask lint found problems in the tree:\n{}",
            diags.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
