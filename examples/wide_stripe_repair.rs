//! Wide-stripe repair scenario: run the full cluster prototype at the
//! paper's widest parameters (P8 = (96,5,4)), inject single- and two-node
//! failures, and compare repair traffic/time across all six schemes.
//! Every repair below rides the plan→compile→execute pipeline: the
//! cluster's `PlanCache` compiles each erasure pattern once and replays
//! the compiled `RepairProgram` per stripe (the per-scheme cache column
//! shows it), and the standalone demo at the end drives the same
//! executor by hand.
//!
//! ```text
//! cargo run --release --example wide_stripe_repair [-- --quick]
//! ```

use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::repair::{RepairProgram, ScratchBuffers, SliceSource};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (k, r, p) = if quick { (24, 2, 2) } else { (96, 5, 4) };
    let block = if quick { 128 * 1024 } else { 512 * 1024 };
    println!("== wide-stripe repair on ({k},{r},{p}), block {} KiB, 1 Gbps ==\n", block / 1024);

    println!(
        "{:<14} {:>9} {:>9} {:>11} {:>11} {:>7}",
        "scheme", "D-repair", "L-repair", "D+L repair", "time (s)", "local%"
    );
    for kind in SchemeKind::ALL_LRC {
        let n = Scheme::new(kind, k, r, p).n();
        let mut c = Cluster::new(ClusterConfig {
            num_datanodes: n + 4,
            gbps: 1.0,
            latency_s: 0.002,
            block_size: block,
            kind,
            k,
            r,
            p,
            ..Default::default()
        });
        let sid = c.fill_random_stripes(1, 0xF00D)[0];
        let lp = c.scheme().local_parity(0);

        // single data-block repair
        let v = c.meta.stripes[&sid].block_nodes[0];
        c.fail_node(v);
        let rep_d = c.repair().stripe(sid, &[0]).run_single()?;
        c.restore_node(v);

        // single local-parity repair
        let v = c.meta.stripes[&sid].block_nodes[lp];
        c.fail_node(v);
        let rep_l = c.repair().stripe(sid, &[lp]).run_single()?;
        c.restore_node(v);

        // D1 + L1 double failure
        let v0 = c.meta.stripes[&sid].block_nodes[0];
        let v1 = c.meta.stripes[&sid].block_nodes[lp];
        c.fail_node(v0);
        c.fail_node(v1);
        let rep_dl = c.repair().stripe(sid, &[0, lp]).run_single()?;
        c.restore_node(v0);
        c.restore_node(v1);
        assert!(c.scrub_stripe(sid)?, "stripe corrupt after repairs");

        // two-node local portion over random patterns
        let mut rng = Prng::new(7);
        let trials = if quick { 20 } else { 60 };
        let mut local = 0;
        for _ in 0..trials {
            let pair = rng.distinct(n, 2);
            if let Some(pl) = cp_lrc::repair::plan(c.scheme(), &pair) {
                if pl.fully_local() {
                    local += 1;
                }
            }
        }

        let cache = c.plan_cache_stats();
        println!(
            "{:<14} {:>7}rd {:>7}rd {:>9}rd {:>11.3} {:>6.0}%   cache {}h/{}m",
            kind.name(),
            rep_d.blocks_read,
            rep_l.blocks_read,
            rep_dl.blocks_read,
            rep_d.total_s() + rep_l.total_s() + rep_dl.total_s(),
            local as f64 / trials as f64 * 100.0,
            cache.hits,
            cache.misses,
        );
    }
    println!("\n(rd = surviving blocks read; lower is better — CP rows should win)");

    // -- the same pipeline, driven by hand ---------------------------------
    // Compile one program for the D1+L1 cascade pattern and replay it
    // over many in-memory stripes with zero per-stripe planning work.
    println!("\n== compile-once / execute-many on CP-Azure ({k},{r},{p}) ==");
    let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, k, r, p));
    let scheme = &codec.scheme;
    let erased = vec![0usize, scheme.local_parity(0)];
    let program = RepairProgram::for_pattern(scheme, &erased)?;
    println!(
        "pattern {:?}: {} survivor reads, fully local = {}",
        erased,
        program.fetch().len(),
        program.plan.fully_local()
    );
    let mut rng = Prng::new(0x71DE);
    let stripes = if quick { 4 } else { 16 };
    let mut originals: Vec<Vec<Vec<u8>>> = Vec::with_capacity(stripes);
    let mut erased_stripes: Vec<Vec<Option<Vec<u8>>>> = Vec::with_capacity(stripes);
    for _ in 0..stripes {
        let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(block / 8)).collect();
        let stripe = codec.encode_stripe(&data);
        let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
        for &e in &erased {
            blocks[e] = None;
        }
        originals.push(stripe);
        erased_stripes.push(blocks);
    }

    // One execute_batch call repairs the whole same-pattern batch: the
    // fetch set is resolved once, scratch is sized once, and each op is
    // a fused multi-source GF combine over cache-blocked columns.
    let mut scratch = ScratchBuffers::new();
    let mut sources: Vec<SliceSource> =
        erased_stripes.iter().map(|b| SliceSource::new(b)).collect();
    let t0 = std::time::Instant::now();
    program.execute_batch(&mut sources, &mut scratch, |si, outs| {
        for (j, &e) in erased.iter().enumerate() {
            anyhow::ensure!(outs[j] == &originals[si][e][..], "stripe {si} block {e} mismatch");
        }
        Ok(())
    })?;
    println!(
        "repaired {stripes} stripes bit-exact in {:.1} ms with one compiled program \
         (one batched execute, fused GF kernels)",
        t0.elapsed().as_secs_f64() * 1000.0
    );
    Ok(())
}
