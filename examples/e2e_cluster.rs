//! End-to-end driver: exercises *every* layer of the system on one real
//! small workload — the validation run recorded in EXPERIMENTS.md.
//!
//! Pipeline: load PJRT artifacts (L1/L2 output) → bring up the cluster
//! (coordinator + proxy + datanode threads) → ingest a mixed small-file
//! workload with CP-Azure (24,2,2) → verify reads → inject single- and
//! two-node failures → repair everything → degraded reads during failure
//! → scrub → report the paper's headline metric (repair time vs Azure
//! LRC) plus throughput.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_cluster
//! ```

use cp_lrc::cluster::degraded::ReadMode;
use cp_lrc::cluster::{Cluster, ClusterConfig, ForegroundLoad};
use cp_lrc::codes::SchemeKind;
use cp_lrc::prng::Prng;
use cp_lrc::runtime::Runtime;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let wall = Instant::now();
    println!("== e2e cluster driver: CP-Azure (24,2,2) vs Azure LRC (24,2,2) ==\n");

    // L1/L2: AOT artifacts (optional — native fallback if absent).
    let rt = Runtime::load_dir(&Runtime::default_dir());
    let rt = match &rt {
        Ok(rt) if !rt.execs.is_empty() => {
            println!("PJRT runtime: {} artifact(s) loaded: {:?}", rt.execs.len(), rt.execs);
            Some(rt)
        }
        _ => {
            println!("PJRT runtime: no artifacts (run `make artifacts`); using native GF path");
            None
        }
    };

    let block = if quick { 256 * 1024 } else { 1024 * 1024 };
    let stripes = if quick { 2 } else { 4 };
    let mut results = Vec::new();
    for kind in [SchemeKind::CpAzure, SchemeKind::AzureLrc] {
        println!("\n--- scheme: {} ---", kind.name());
        let cfg = ClusterConfig {
            num_datanodes: 32,
            gbps: 1.0,
            latency_s: 0.002,
            block_size: block,
            kind,
            k: 24,
            r: 2,
            p: 2,
            ..Default::default()
        };
        let mut c = Cluster::new(cfg);
        if let Some(rt) = rt {
            c = c.with_runtime(rt);
        }

        // Ingest: a mix of small and large files (small-file aggregation).
        let mut rng = Prng::new(0xE2E);
        let mut files = Vec::new();
        for _ in 0..stripes {
            for _ in 0..12 {
                let size = 1024 + rng.below(block);
                let content = rng.bytes(size);
                let id = c.put_file(content.clone());
                files.push((id, content));
            }
            c.seal_stripe();
        }
        println!(
            "ingested {} files into {} stripes ({} MiB data), metadata {:.1} KiB",
            files.len(),
            c.meta.stripes.len(),
            c.meta.stripes.len() * 24 * block / (1024 * 1024),
            c.meta.footprint_bytes() as f64 / 1024.0
        );

        // Verify normal reads.
        for (id, content) in &files {
            let (out, _) = c.read_file(*id).expect("read");
            assert_eq!(&out, content, "read mismatch for file {id}");
        }
        println!("verified {} normal reads ✓", files.len());

        // Single-node failures: fail the node behind one block of each
        // type (data, first global, last global, local parity) in turn —
        // the paper's §VI-B1 "repair the failed block in each stripe in
        // turn" methodology, sampled across block classes.
        let scheme = c.scheme().clone();
        let positions = [0usize, 24, 24 + 1, scheme.local_parity(0)];
        let mut t1_sum = 0.0;
        let mut t1_pipe = 0.0;
        let mut n1 = 0usize;
        let mut blocks_read = 0usize;
        let mut degraded = 0usize;
        let mut sess_done = 0.0f64;
        let mut sess_serial = 0.0f64;
        let mut sess_wb_overlap = 0.0f64;
        for (pi, &pos) in positions.iter().enumerate() {
            let victim = c.meta.stripes[&0].block_nodes[pos];
            c.fail_node(victim);
            if pi == 0 {
                // degraded reads still work during the failure
                for (id, content) in files.iter().take(8) {
                    let rep = c.degraded_read(*id, ReadMode::FileLevelDedup)?;
                    assert_eq!(&rep.bytes, content);
                    degraded += usize::from(rep.degraded);
                }
            }
            // Whole-node repair as one TrafficPlane session: 4 decode
            // workers, all stripes' fetches + write-backs contending on
            // one shared timeline (per-stripe isolated accounting is
            // retained on each report).
            let session = c.repair().threads(4).run()?;
            for r in &session.reports {
                assert!(r.completion_s <= r.total_s() + 1e-9, "pipelined must not lose to wave");
                assert!(
                    r.contended_read_s >= r.read_s - 1e-9,
                    "contention cannot speed a fetch up"
                );
                t1_sum += r.total_s();
                t1_pipe += r.completion_s;
                blocks_read += r.blocks_read;
                n1 += 1;
            }
            assert!(session.completion_s <= session.serial_s + 1e-6);
            sess_done += session.completion_s;
            sess_serial += session.serial_s;
            sess_wb_overlap += session.write_back_overlap_s;
            c.restore_node(victim);
        }
        let t1 = t1_sum / n1 as f64;
        println!(
            "single-node failures (D/G1/G2/L1 positions): {} repairs, avg {:.3}s, {} blocks read, {} degraded reads served",
            n1, t1, blocks_read, degraded
        );
        println!(
            "  fetch/decode overlap (EXPERIMENTS.md §Overlap): avg {:.3}s pipelined vs {:.3}s wave ({:.1}% saved)",
            t1_pipe / n1 as f64,
            t1,
            100.0 * (1.0 - t1_pipe / t1_sum)
        );
        println!(
            "  shared timeline (EXPERIMENTS.md §Contention): {:.3}s contended session vs {:.3}s serial bound ({:.1}% saved, {:.4}s from write-back overlap)",
            sess_done,
            sess_serial,
            100.0 * (1.0 - sess_done / sess_serial),
            sess_wb_overlap
        );

        // Two-node failure (D and L of stripe 0 where possible), this
        // time with in-session degraded reads and a 25% foreground load
        // sharing the session's timeline.
        let lp = c.scheme().local_parity(0);
        let v0 = c.meta.stripes[&0].block_nodes[1];
        let v1 = c.meta.stripes[&0].block_nodes[lp];
        c.fail_node(v0);
        c.fail_node(v1);
        let session2 = c
            .repair()
            .threads(4)
            .foreground(ForegroundLoad { fraction: 0.25, request_bytes: block as u64, seed: 7 })
            .degraded_reads(files.iter().take(2).map(|(id, _)| (*id, ReadMode::FileLevelDedup)))
            .run()?;
        for (read, (_, content)) in session2.reads.iter().zip(files.iter().take(2)) {
            assert_eq!(&read.bytes, content, "in-session degraded read mismatch");
        }
        let reports2 = &session2.reports;
        let t2: f64 = reports2.iter().map(|r| r.total_s()).sum::<f64>() / reports2.len() as f64;
        println!(
            "two-node failure under 25% foreground load: {} stripes repaired, avg {:.3}s, local={}, session {:.3}s ({:.3}s contention), {} fg requests served",
            reports2.len(),
            t2,
            reports2.iter().filter(|r| r.local).count(),
            session2.completion_s,
            session2.contention_delay_s,
            session2.foreground.as_ref().map_or(0, |f| f.requests_completed)
        );
        c.restore_node(v0);
        c.restore_node(v1);

        // Scrub everything.
        for sid in c.meta.stripes.keys().copied().collect::<Vec<_>>() {
            assert!(c.scrub_stripe(sid)?, "stripe {sid} failed scrub");
        }
        println!("all stripes scrub clean ✓");
        results.push((kind, t1, t2));
    }

    let (_, cp1, cp2) = results[0];
    let (_, az1, az2) = results[1];
    println!("\n== headline ==");
    println!(
        "single-node repair time: CP-Azure {:.3}s vs Azure LRC {:.3}s  ({:.1}% reduction)",
        cp1,
        az1,
        (1.0 - cp1 / az1) * 100.0
    );
    println!(
        "two-node repair time:    CP-Azure {:.3}s vs Azure LRC {:.3}s  ({:.1}% reduction)",
        cp2,
        az2,
        (1.0 - cp2 / az2) * 100.0
    );
    println!("\ne2e driver completed in {:.1}s wall-clock", wall.elapsed().as_secs_f64());
    Ok(())
}
