//! Trace replay: the Fig-10 experiment as a standalone application —
//! generate the FB-2010-profile file population, store it with Azure LRC,
//! crash a node, and replay degraded reads with and without the §V-C
//! file-level optimization.
//!
//! ```text
//! cargo run --release --example trace_replay [-- --quick]
//! ```

use cp_lrc::cluster::degraded::ReadMode;
use cp_lrc::cluster::{Cluster, ClusterConfig};
use cp_lrc::codes::SchemeKind;
use cp_lrc::prng::Prng;
use cp_lrc::trace::{self, SizeClass};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = trace::TraceConfig {
        n_files: if quick { 25 } else { 100 },
        max_size: if quick { 2 * 1024 * 1024 } else { 30 * 1024 * 1024 },
        ..Default::default()
    };
    let block = if quick { 512 * 1024 } else { 16 * 1024 * 1024 };
    println!(
        "== trace replay: {} files (5 KB..{} MB), Azure LRC (6,2,2), {} KiB blocks ==\n",
        cfg.n_files,
        cfg.max_size / (1024 * 1024),
        block / 1024
    );

    let files = trace::generate(&cfg);
    let mut c = Cluster::new(ClusterConfig {
        num_datanodes: 14,
        gbps: 1.0,
        latency_s: 0.002,
        block_size: block,
        kind: SchemeKind::AzureLrc,
        k: 6,
        r: 2,
        p: 2,
        ..Default::default()
    });
    let mut rng = Prng::new(3);
    let ids: Vec<_> = files
        .iter()
        .map(|f| {
            let mut content = vec![0u8; f.size];
            rng.fill(&mut content);
            c.put_file(content)
        })
        .collect();
    c.seal_stripe();
    println!(
        "stored {} files in {} stripes; metadata footprint {:.1} KiB\n",
        files.len(),
        c.meta.stripes.len(),
        c.meta.footprint_bytes() as f64 / 1024.0
    );

    c.fail_node(0);
    let ops = trace::read_ops(&files, 1, 11);
    let mut sums: std::collections::HashMap<SizeClass, (f64, f64, usize)> = Default::default();
    let mut checked = 0;
    for &i in &ops {
        let base = c.degraded_read(ids[i], ReadMode::BlockLevel)?;
        let opt = c.degraded_read(ids[i], ReadMode::FileLevelDedup)?;
        assert_eq!(base.bytes, opt.bytes);
        checked += 1;
        let e = sums.entry(SizeClass::of(files[i].size)).or_default();
        e.0 += base.time_s * 1000.0;
        e.1 += opt.time_s * 1000.0;
        e.2 += 1;
    }
    println!("replayed {checked} reads (data verified on every one)\n");
    println!("{:<16} {:>6} {:>16} {:>16} {:>8}", "class", "reads", "block-level(ms)", "file-level(ms)", "gain");
    let (mut tb, mut to, mut tn) = (0.0, 0.0, 0usize);
    for class in [SizeClass::Small, SizeClass::Medium, SizeClass::Large] {
        if let Some(&(b, o, n)) = sums.get(&class) {
            println!(
                "{:<16} {:>6} {:>16.1} {:>16.1} {:>7.1}%",
                class.label(),
                n,
                b / n as f64,
                o / n as f64,
                (1.0 - o / b) * 100.0
            );
            tb += b;
            to += o;
            tn += n;
        }
    }
    println!(
        "{:<16} {:>6} {:>16.1} {:>16.1} {:>7.1}%",
        "all",
        tn,
        tb / tn as f64,
        to / tn as f64,
        (1.0 - to / tb) * 100.0
    );
    Ok(())
}
