//! Quickstart: encode a CP-Azure stripe, lose two blocks, repair them,
//! and show the cascaded-parity advantage next to plain Azure LRC.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use cp_lrc::codec::StripeCodec;
use cp_lrc::codes::{Scheme, SchemeKind};
use cp_lrc::prng::Prng;
use cp_lrc::repair;

fn main() -> anyhow::Result<()> {
    let (k, r, p) = (24, 2, 2);
    println!("== CP-LRC quickstart: ({k},{r},{p}) wide stripe ==\n");

    // 1. Build the code and encode a stripe of random data.
    let codec = StripeCodec::new(Scheme::new(SchemeKind::CpAzure, k, r, p));
    let scheme = codec.scheme.clone();
    let mut rng = Prng::new(1);
    let block = 64 * 1024;
    let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(block)).collect();
    let stripe = codec.encode_stripe(&data);
    println!(
        "encoded {} data blocks (+{} global, +{} local parities), {} KiB each",
        k,
        r,
        p,
        block / 1024
    );

    // The cascade identity: L1 + ... + Lp == Gr, bytewise.
    let mut cascade = vec![0u8; block];
    for j in 0..p {
        cp_lrc::gf::xor_slice(&mut cascade, &stripe[scheme.local_parity(j)]);
    }
    assert_eq!(cascade, stripe[k + r - 1]);
    println!("cascade identity holds: L1 ^ ... ^ Lp == G{r}\n");

    // 2. Fail D1 and L1 simultaneously — the paper's §III motivating case.
    let erased = vec![0usize, scheme.local_parity(0)];
    println!(
        "failing {} and {} ...",
        scheme.block_name(erased[0]),
        scheme.block_name(erased[1])
    );
    let plan = repair::plan(&scheme, &erased).expect("recoverable");
    println!(
        "  CP-Azure plan: {} ({} blocks read: {})",
        if plan.fully_local() { "two-step LOCAL repair" } else { "global repair" },
        plan.cost(k),
        plan.reads.iter().map(|&b| scheme.block_name(b)).collect::<Vec<_>>().join(",")
    );

    let azure = Scheme::new(SchemeKind::AzureLrc, k, r, p);
    let plan_azure = repair::plan(&azure, &erased).expect("recoverable");
    println!(
        "  Azure LRC plan: {} ({} blocks read)",
        if plan_azure.fully_local() { "local" } else { "GLOBAL repair" },
        plan_azure.cost(k)
    );
    println!(
        "  -> cascading cuts repair bandwidth {}x ({} vs {} blocks)\n",
        plan_azure.cost(k) as f64 / plan.cost(k) as f64,
        plan.cost(k),
        plan_azure.cost(k)
    );

    // 3. Execute the plan on the real bytes and verify.
    let mut blocks: Vec<Option<Vec<u8>>> = stripe.iter().cloned().map(Some).collect();
    for &e in &erased {
        blocks[e] = None;
    }
    let rec = repair::execute(&codec, &plan, &blocks)?;
    for (i, &e) in erased.iter().enumerate() {
        assert_eq!(rec[i], stripe[e], "reconstruction mismatch");
    }
    println!("reconstructed blocks verified bit-for-bit ✓");

    // 4. Single-block repair costs, the Table I story in one stripe.
    println!("\nsingle-block repair costs (blocks read):");
    for b in [0, k, k + r - 1, scheme.local_parity(0)] {
        let pl = repair::plan_single(&scheme, b);
        println!("  {:<4} -> {}", scheme.block_name(b), pl.cost(k));
    }
    Ok(())
}
