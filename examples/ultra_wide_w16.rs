//! Ultra-wide stripes beyond GF(2^8): a (300, 4) Cauchy-RS stripe over
//! GF(2^16) — the regime the paper's introduction motivates (Vastdata
//! 150+4, 1024-wide academic deployments) where k + r > 256 makes w = 8
//! impossible. Demonstrates the `gf::w16` substrate end to end and shows
//! why plain ultra-wide MDS repair is untenable (the LRC motivation).
//!
//! ```text
//! cargo run --release --example ultra_wide_w16
//! ```

use cp_lrc::gf::w16::WideRs16;
use cp_lrc::prng::Prng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let (k, r) = (300usize, 4usize);
    let block = 32 * 1024;
    println!("== ultra-wide ({k},{r}) Cauchy-RS over GF(2^16), {} KiB blocks ==\n", block / 1024);
    println!("storage overhead: {:.2}% (rate {:.4})", r as f64 / k as f64 * 100.0, k as f64 / (k + r) as f64);

    let rs = WideRs16::new(k, r);
    let mut rng = Prng::new(0x1616);
    let data: Vec<Vec<u8>> = (0..k).map(|_| rng.bytes(block)).collect();

    let t = Instant::now();
    let parity = rs.encode(&data);
    let enc = t.elapsed();
    println!(
        "encoded {} MiB in {:.2?} ({:.2} GiB/s)",
        k * block / (1024 * 1024),
        enc,
        (k * block) as f64 / enc.as_secs_f64() / (1 << 30) as f64
    );

    // Fail r blocks and reconstruct.
    let mut blocks: Vec<Option<Vec<u8>>> =
        data.iter().chain(parity.iter()).cloned().map(Some).collect();
    let erased = vec![7usize, 142, 299, k + 1];
    for &e in &erased {
        blocks[e] = None;
    }
    let t = Instant::now();
    let rec = rs.decode(&blocks, &erased)?;
    let dec = t.elapsed();
    for (i, &e) in erased.iter().enumerate() {
        let want = if e < k { &data[e] } else { &parity[e - k] };
        assert_eq!(&rec[i], want, "block {e}");
    }
    println!("reconstructed {} erasures in {:.2?} — verified ✓", erased.len(), dec);

    // The wide-stripe problem in one number (paper §I):
    println!(
        "\nsingle-block repair under plain ({k},{r}) MDS touches {k} survivors\n\
         ({:.0} MiB moved to rebuild one {} KiB block — the cost CP-LRCs'\n\
         locality exists to avoid; see `quickstart` for the LRC fix).",
        (k * block) as f64 / (1024.0 * 1024.0),
        block / 1024
    );
    Ok(())
}
